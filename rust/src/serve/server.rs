//! The scoring server: listener, connection handling, endpoint dispatch,
//! and the hot-swappable model slot. See the module doc in
//! [`crate::serve`] for the request lifecycle and swap semantics.

use std::io::BufRead;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::error::Result;
use crate::util::json::{self, Json};

use super::http::{self, ChunkedWriter, ReadError, Request};
use super::{canonicalize, prediction_line, ServedModel};

/// Hard cap on request bodies (batches are capped by `max_batch` anyway;
/// this bounds what a client can make the server buffer).
const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// Idle-poll cadence on keep-alive connections: how often a parked
/// connection checks the shutdown flag.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Once a request has started arriving, how long the server waits for
/// the rest of it before giving up on the connection.
const REQUEST_READ_TIMEOUT: Duration = Duration::from_secs(10);

/// The live model: an `Arc` behind a `RwLock`. Readers clone the `Arc`
/// under a brief read lock and score lock-free; the watcher replaces the
/// whole `Arc` under the write lock. In-flight requests keep the model
/// they started with — a swap is atomic, never torn.
pub struct ModelSlot {
    inner: RwLock<Arc<ServedModel>>,
}

impl ModelSlot {
    pub fn new(model: ServedModel) -> Self {
        Self { inner: RwLock::new(Arc::new(model)) }
    }

    pub fn get(&self) -> Arc<ServedModel> {
        // a poisoned lock only means a panic elsewhere; the stored Arc is
        // always a fully-constructed model, so serving must not stop
        self.inner.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    pub fn swap(&self, model: ServedModel) {
        *self.inner.write().unwrap_or_else(|e| e.into_inner()) = Arc::new(model);
    }
}

/// Monotonic serving counters, exposed at `GET /metrics`.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub predictions: AtomicU64,
    pub swaps: AtomicU64,
    pub swap_failures: AtomicU64,
    pub client_errors: AtomicU64,
    pub server_errors: AtomicU64,
}

impl ServeStats {
    fn to_json(&self) -> String {
        format!(
            "{{\"client_errors\":{},\"predictions\":{},\"requests\":{},\
             \"server_errors\":{},\"swap_failures\":{},\"swaps\":{}}}",
            self.client_errors.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.requests.load(Ordering::Relaxed),
            self.server_errors.load(Ordering::Relaxed),
            self.swap_failures.load(Ordering::Relaxed),
            self.swaps.load(Ordering::Relaxed),
        )
    }
}

/// Builder entry point for the serving subsystem.
pub struct Server;

impl Server {
    /// Load + validate the artifact, bind, and start serving. Returns
    /// once the listener is live (the caller prints the ready line).
    pub fn start(model_path: impl AsRef<Path>, cfg: &ServeConfig) -> Result<ServerHandle> {
        cfg.validate()?;
        let model_path: PathBuf = model_path.as_ref().to_path_buf();
        let model = ServedModel::load(&model_path)?;
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?;
        let slot = Arc::new(ModelSlot::new(model));
        let stats = Arc::new(ServeStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let listener = Arc::new(listener);
        let mut threads = Vec::with_capacity(cfg.threads);
        for t in 0..cfg.threads {
            let listener = Arc::clone(&listener);
            let slot = Arc::clone(&slot);
            let stats = Arc::clone(&stats);
            let shutdown = Arc::clone(&shutdown);
            let max_batch = cfg.max_batch;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("serve-accept-{t}"))
                    .spawn(move || {
                        accept_loop(&listener, slot, stats, shutdown, max_batch)
                    })
                    .expect("spawn accept thread"),
            );
        }
        let watcher = if cfg.watch {
            Some(super::swap::spawn_watcher(
                model_path,
                Arc::clone(&slot),
                Arc::clone(&stats),
                Duration::from_secs_f64(cfg.poll_interval_secs),
                Arc::clone(&shutdown),
            ))
        } else {
            None
        };
        Ok(ServerHandle { addr, slot, stats, shutdown, threads, watcher })
    }
}

/// A running server. Dropping the handle does NOT stop it — call
/// [`ServerHandle::stop`] (or let the process exit).
pub struct ServerHandle {
    pub addr: SocketAddr,
    pub slot: Arc<ModelSlot>,
    pub stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown, unblock the accept threads, and join everything.
    /// Parked keep-alive connections notice within one idle poll.
    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for _ in 0..self.threads.len() {
            // each dial wakes one accept() call; the woken thread sees
            // the flag and exits without handling the connection
            let _ = TcpStream::connect(self.addr);
        }
        for t in self.threads {
            let _ = t.join();
        }
        if let Some(w) = self.watcher {
            let _ = w.join();
        }
    }

    /// Block until the process is killed (the CLI path).
    pub fn wait(mut self) {
        if let Some(w) = self.watcher.take() {
            let _ = w.join();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    slot: Arc<ModelSlot>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        // one detached handler per connection: a parked keep-alive
        // session must not block this thread from accepting new clients
        let slot = Arc::clone(&slot);
        let stats = Arc::clone(&stats);
        let shutdown = Arc::clone(&shutdown);
        let _ = std::thread::Builder::new()
            .name("serve-conn".into())
            .spawn(move || handle_connection(stream, &slot, &stats, &shutdown, max_batch));
    }
}

fn handle_connection(
    mut stream: TcpStream,
    slot: &ModelSlot,
    stats: &ServeStats,
    shutdown: &AtomicBool,
    max_batch: usize,
) {
    stream.set_nodelay(true).ok();
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(reader_stream);
    loop {
        // park until the next request's first byte (or shutdown/EOF)
        if stream.set_read_timeout(Some(IDLE_POLL)).is_err() {
            return;
        }
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            match reader.fill_buf() {
                Ok([]) => return, // clean EOF between requests
                Ok(_) => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                Err(_) => return,
            }
        }
        // a request is arriving: give it a generous (but finite) deadline
        if stream.set_read_timeout(Some(REQUEST_READ_TIMEOUT)).is_err() {
            return;
        }
        let req = match http::read_request(&mut reader, &mut stream, MAX_BODY_BYTES) {
            Ok(req) => req,
            Err(ReadError::Closed) => return,
            Err(ReadError::Bad(msg)) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.client_errors.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_response(&mut stream, 400, &error_body(&msg), false);
                return; // framing is broken: the stream is not re-syncable
            }
            Err(ReadError::TooLarge { declared, limit }) => {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.client_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("request body of {declared} bytes exceeds the {limit} byte cap");
                let _ = http::write_response(&mut stream, 413, &error_body(&msg), false);
                return; // the unread body would desync the stream
            }
            Err(ReadError::Io(_)) => return,
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = req.keep_alive();
        if dispatch(&mut stream, &req, slot, stats, max_batch, keep_alive).is_err() {
            return; // client went away mid-response
        }
        if !keep_alive {
            return;
        }
    }
}

fn error_body(msg: &str) -> String {
    // Json::Str handles escaping
    format!("{{\"error\":{}}}", Json::Str(msg.to_string()))
}

fn dispatch(
    stream: &mut TcpStream,
    req: &Request,
    slot: &ModelSlot,
    stats: &ServeStats,
    max_batch: usize,
    keep_alive: bool,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let m = slot.get();
            let body = format!(
                "{{\"lambda\":{},\"model_version\":\"{}\",\"n\":{},\"nnz\":{},\
                 \"p\":{},\"solver\":{},\"status\":\"ok\"}}",
                m.model.lambda,
                m.version,
                m.model.n_examples,
                m.model.nnz(),
                m.model.n_features,
                Json::Str(m.model.solver.clone()),
            );
            http::write_response(stream, 200, &body, keep_alive)
        }
        ("GET", "/metrics") => http::write_response(stream, 200, &stats.to_json(), keep_alive),
        ("POST", "/predict") => handle_predict(stream, req, slot, stats, keep_alive),
        ("POST", "/predict_batch") => {
            handle_predict_batch(stream, req, slot, stats, max_batch, keep_alive)
        }
        (_, "/healthz" | "/metrics" | "/predict" | "/predict_batch") => {
            stats.client_errors.fetch_add(1, Ordering::Relaxed);
            let msg = format!("method {} not allowed on {}", req.method, req.path);
            http::write_response(stream, 405, &error_body(&msg), keep_alive)
        }
        (_, path) => {
            stats.client_errors.fetch_add(1, Ordering::Relaxed);
            let msg = format!(
                "no such endpoint '{path}' (have /predict, /predict_batch, /healthz, /metrics)"
            );
            http::write_response(stream, 404, &error_body(&msg), keep_alive)
        }
    }
}

/// Pull one `{"indices":[..],"values":[..]}` example out of a JSON value
/// into canonical (sorted, deduplicated) column/value arrays.
fn parse_example(v: &Json) -> std::result::Result<(Vec<u32>, Vec<f32>), String> {
    let idx = v
        .get("indices")
        .and_then(Json::as_arr)
        .ok_or_else(|| "example needs an 'indices' array".to_string())?;
    let vals = v
        .get("values")
        .and_then(Json::as_arr)
        .ok_or_else(|| "example needs a 'values' array".to_string())?;
    if idx.len() != vals.len() {
        return Err(format!(
            "indices/values length mismatch ({} vs {})",
            idx.len(),
            vals.len()
        ));
    }
    let mut pairs = Vec::with_capacity(idx.len());
    for (i, (ji, vi)) in idx.iter().zip(vals).enumerate() {
        let j = ji
            .as_f64()
            .ok_or_else(|| format!("indices[{i}] is not a number"))?;
        if j < 0.0 || j.fract() != 0.0 || j > u32::MAX as f64 {
            return Err(format!("indices[{i}] = {j} is not a valid feature id"));
        }
        let v = vi
            .as_f64()
            .ok_or_else(|| format!("values[{i}] is not a number"))?;
        if !v.is_finite() {
            return Err(format!("values[{i}] is not finite"));
        }
        pairs.push((j as u32, v as f32));
    }
    Ok(canonicalize(pairs))
}

fn parse_body(req: &Request) -> std::result::Result<Json, String> {
    let text = std::str::from_utf8(&req.body).map_err(|_| "body is not utf-8".to_string())?;
    json::parse(text).map_err(|e| format!("bad JSON: {e}"))
}

fn handle_predict(
    stream: &mut TcpStream,
    req: &Request,
    slot: &ModelSlot,
    stats: &ServeStats,
    keep_alive: bool,
) -> std::io::Result<()> {
    let (cols, vals) = match parse_body(req).and_then(|v| parse_example(&v)) {
        Ok(x) => x,
        Err(msg) => {
            stats.client_errors.fetch_add(1, Ordering::Relaxed);
            return http::write_response(stream, 400, &error_body(&msg), keep_alive);
        }
    };
    let model = slot.get();
    let (margin, proba) = model.score(&cols, &vals);
    stats.predictions.fetch_add(1, Ordering::Relaxed);
    let body = format!(
        "{{\"margin\":{margin},\"model_version\":\"{}\",\"proba\":{proba}}}",
        model.version
    );
    http::write_response(stream, 200, &body, keep_alive)
}

fn handle_predict_batch(
    stream: &mut TcpStream,
    req: &Request,
    slot: &ModelSlot,
    stats: &ServeStats,
    max_batch: usize,
    keep_alive: bool,
) -> std::io::Result<()> {
    let examples = match parse_body(req) {
        Ok(v) => match v.get("examples").and_then(Json::as_arr) {
            Some(arr) => {
                if arr.len() > max_batch {
                    stats.client_errors.fetch_add(1, Ordering::Relaxed);
                    let msg = format!(
                        "batch of {} examples exceeds max_batch = {max_batch}; split the request",
                        arr.len()
                    );
                    return http::write_response(stream, 413, &error_body(&msg), keep_alive);
                }
                arr.to_vec()
            }
            None => {
                stats.client_errors.fetch_add(1, Ordering::Relaxed);
                let msg = "batch request needs an 'examples' array";
                return http::write_response(stream, 400, &error_body(msg), keep_alive);
            }
        },
        Err(msg) => {
            stats.client_errors.fetch_add(1, Ordering::Relaxed);
            return http::write_response(stream, 400, &error_body(&msg), keep_alive);
        }
    };
    // validate everything BEFORE streaming: once the 200 header is out,
    // the status can no longer change
    let mut parsed = Vec::with_capacity(examples.len());
    for (i, ex) in examples.iter().enumerate() {
        match parse_example(ex) {
            Ok(x) => parsed.push(x),
            Err(msg) => {
                stats.client_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("examples[{i}]: {msg}");
                return http::write_response(stream, 400, &error_body(&msg), keep_alive);
            }
        }
    }
    // one snapshot for the whole batch: a mid-batch hot-swap never mixes
    // model versions within one response
    let model = slot.get();
    let mut writer = ChunkedWriter::start(
        stream,
        200,
        "application/x-ndjson",
        keep_alive,
        &[("X-Model-Version", model.version.as_str())],
    )?;
    let mut line = String::new();
    for (i, (cols, vals)) in parsed.iter().enumerate() {
        let (margin, proba) = model.score(cols, vals);
        line.clear();
        line.push_str(&prediction_line(i, margin, proba));
        line.push('\n');
        writer.write_chunk(line.as_bytes())?;
    }
    stats
        .predictions
        .fetch_add(parsed.len() as u64, Ordering::Relaxed);
    writer.finish()
}
