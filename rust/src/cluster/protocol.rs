//! The serializable leader ↔ worker message protocol. Every interaction
//! with a cluster node — handshake, sweep requests, update application,
//! state push/pull, shutdown — is one [`NodeMessage`], so the same
//! `FitDriver` send/recv phases run unchanged over in-process channels and
//! over a real multi-process byte stream (see [`crate::cluster::transport`]).
//!
//! Sparse payloads are framed with the PR-3 wire codecs
//! ([`crate::cluster::codec`]): each message embeds the codec tag the
//! lossless byte-cost model picked, so under the default (lossless)
//! policy the bytes a [`SocketTransport`] actually writes for a Δ-payload
//! equal the codec cost functions the simulated `comm_bytes` ledger
//! charges per tree edge — the wire and the ledger agree byte-for-byte on
//! payload encoding. (The ledger models *tree-edge* traffic of the
//! collectives; transport-level control frames and the leader-star
//! topology of a small deployment are deliberately not charged — see the
//! accounting contract in [`crate::cluster`]. With the opt-in lossy
//! `wire_f16_*` knobs the ledger charges the delta-varint f16 cost while
//! these frames stay losslessly encoded — the values are already
//! quantized inside the collective, so trajectories are unaffected and
//! the socket frames are an upper bound on the charged bytes.)
//!
//! [`SocketTransport`]: crate::cluster::transport::SocketTransport
//!
//! Malformed and truncated frames error exactly like the codec truncation
//! tests: every decode returns a `parse` error, never a panic and never a
//! silently-wrong value.

use std::sync::Arc;

use crate::cluster::codec::{CodecPolicy, MessageClass, WireCodec};
use crate::data::sparse::SparseVec;
use crate::engine::SweepResult;
use crate::error::{DlrError, Result};

/// Upper bound on one frame body — a guard against garbage length prefixes
/// from a rogue or corrupted peer, not a protocol limit.
pub const MAX_FRAME_BODY: usize = 1 << 30;

const TAG_JOIN: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_SWEEP: u8 = 3;
const TAG_SWEPT: u8 = 4;
const TAG_APPLY: u8 = 5;
const TAG_SET_STATE: u8 = 6;
const TAG_GET_STATE: u8 = 7;
const TAG_STATE: u8 = 8;
const TAG_ACK: u8 = 9;
const TAG_ABORT: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;
const TAG_LAMBDA_MAX: u8 = 12;
const TAG_LAMBDA_MAXED: u8 = 13;
const TAG_MARGINS: u8 = 14;
const TAG_MARGINS_PART: u8 = 15;
const TAG_PING: u8 = 16;
const TAG_PONG: u8 = 17;

/// One protocol message between the leader and a worker node.
///
/// Workers are *stateful* endpoints (see [`crate::cluster::node`]): they
/// hold their own β shard and margins, so a [`NodeMessage::Sweep`] carries
/// only the scalars of the subproblem and a [`NodeMessage::Apply`] carries
/// only the step size plus the merged Δmargins — the per-sweep
/// `beta_local` / `(w, z)` broadcasts of the pre-protocol `WorkerPool` are
/// gone entirely.
#[derive(Debug)]
pub enum NodeMessage {
    /// worker → leader: handshake. The leader validates the shard identity
    /// (machine index, dataset shape, owned-column checksum) and the GLM
    /// family the worker was configured with before admitting the node — a
    /// worker deriving (w, z) under a different family would silently
    /// corrupt the optimization.
    Join {
        machine: u32,
        n: u32,
        p: u32,
        local_features: u32,
        cols_checksum: u64,
        engine: String,
        family: String,
    },
    /// leader → worker: handshake accepted. Carries the run's GLM family
    /// and elastic-net α so a socket worker can double-check its own
    /// configuration against the leader's (the in-process pool constructs
    /// workers from the same `TrainConfig`, so its nodes skip the check).
    Welcome { family: String, alpha: f64 },
    /// leader → worker: run one CD sweep over the worker-held shard state.
    /// `lam` is the soft-threshold (L1) strength λ·α and `l2` the ridge
    /// strength λ·(1−α) added to each coordinate's denominator (0 under the
    /// default pure-L1 configuration). `recycle` is an owned-buffer
    /// recycling slot for the in-process transport (the previous
    /// iteration's [`SweepResult`] buffers round trip so steady-state
    /// sweeps allocate nothing); it is *not* encoded on the wire — a socket
    /// worker fills a fresh default.
    Sweep { lam: f32, nu: f32, l2: f32, recycle: SweepResult },
    /// worker → leader: the sweep's sparse Δβ (shard-local ids) and Δm.
    Swept { result: SweepResult },
    /// leader → worker: line search picked `alpha`; apply `α·Δβ_local` to
    /// the worker-held β shard and `α·Δm` (the merged, post-codec
    /// Δmargins) to the worker-held margins. `delta` carries the merged
    /// global Δβ only when a lossy β wire is active (`wire_f16_beta`), so
    /// workers apply exactly what the leader applied; on the default
    /// lossless wire each worker's own Δβ already equals the merged values
    /// on its coordinates (disjoint feature partition) and nothing
    /// β-shaped needs to travel.
    Apply {
        alpha: f32,
        dmargins: Arc<SparseVec>,
        delta: Option<Arc<SparseVec>>,
    },
    /// leader → worker: install warmstart / resume state bit-for-bit.
    SetState {
        beta_local: Vec<f32>,
        margins: Arc<Vec<f32>>,
    },
    /// leader → worker: report the worker-held shard state (checkpointing).
    GetState,
    /// worker → leader: the shard state. Margins travel as a checksum — the
    /// leader only needs to *verify* sync, β travels in full for the
    /// checkpoint.
    State { beta_local: Vec<f32>, margins_crc: u64 },
    /// leader → worker: report this shard's λ_max contribution
    /// `max_j |Σ_i x_ij t_i| · scale` over its own features (targets `t`
    /// and `scale` come from the node's GLM family; logistic: `t = y`,
    /// `scale = 1/2`) — part of the
    /// distributed reduce that lets an out-of-core leader find λ_max
    /// without ever holding X (each per-feature f64 sum is bit-identical
    /// to the in-memory scan; the max over disjoint shards is exact).
    LambdaMax,
    /// worker → leader: the shard's λ_max contribution.
    LambdaMaxed { value: f64 },
    /// leader → worker: compute the shard's margins product
    /// `Σ_{j ∈ shard} β_j x_ij` for the given shard-local β — the
    /// distributed warmstart install. Stateless: the node's own (β,
    /// margins) are untouched (the leader follows up with a `SetState`).
    Margins { beta_local: Vec<f32> },
    /// worker → leader: the shard's sparse margins contribution.
    MarginsPart { part: SparseVec },
    /// leader → worker: liveness probe. A healthy node answers
    /// [`NodeMessage::Pong`] immediately; the supervisor uses the
    /// ping/pong pair (under a recv deadline) both to detect wedged
    /// workers and to drain at most one stale reply left on a link by a
    /// failed phase — the protocol is strictly request/reply, so one
    /// un-consumed message is the worst case.
    Ping,
    /// worker → leader: the heartbeat answer.
    Pong,
    /// worker → leader: acknowledgement of an `Apply` / `SetState`.
    Ack,
    /// either direction: the peer failed; the message is the error.
    Abort { message: String },
    /// leader → worker: clean shutdown, the serve loop exits.
    Shutdown,
}

/// An [`NodeMessage::Abort`] is last-words courtesy to a peer that may
/// already be gone, so its send failing is expected — but it must never be
/// *silently* swallowed: a peer that misses the abort will sit blocked
/// until its own read fails. Every abort-send site routes through here so
/// the loss is logged once, with the machine id and the phase it happened
/// in.
pub(crate) fn log_lost_abort(
    machine: usize,
    context: &str,
    err: &dyn std::fmt::Display,
) {
    eprintln!(
        "[cluster] could not deliver abort to worker {machine} during {context}: {err}"
    );
}

// ---------------------------------------------------------------------------
// Checksums (FNV-1a — cheap, deterministic, dependency-free)
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over the f32 bit patterns — the margins-sync check of
/// [`NodeMessage::State`].
pub fn crc_f32(values: &[f32]) -> u64 {
    values.iter().fold(FNV_OFFSET, |h, v| fnv1a(h, &v.to_bits().to_le_bytes()))
}

/// FNV-1a over u32 little-endian bytes — the owned-column identity check of
/// [`NodeMessage::Join`].
pub fn crc_u32(values: &[u32]) -> u64 {
    values.iter().fold(FNV_OFFSET, |h, v| fnv1a(h, &v.to_le_bytes()))
}

// ---------------------------------------------------------------------------
// Primitive (en/de)coders
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn take<'a>(bytes: &'a [u8], pos: &mut usize, len: usize) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| DlrError::parse("wire", "truncated frame"))?;
    let s = &bytes[*pos..end];
    *pos = end;
    Ok(s)
}

fn get_u8(bytes: &[u8], pos: &mut usize) -> Result<u8> {
    Ok(take(bytes, pos, 1)?[0])
}

fn get_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let s = take(bytes, pos, 4)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

fn get_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let s = take(bytes, pos, 8)?;
    Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
}

fn get_f32(bytes: &[u8], pos: &mut usize) -> Result<f32> {
    Ok(f32::from_bits(get_u32(bytes, pos)?))
}

fn get_f64(bytes: &[u8], pos: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(get_u64(bytes, pos)?))
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_u32(bytes, pos)? as usize;
    let s = take(bytes, pos, len)?;
    String::from_utf8(s.to_vec()).map_err(|_| DlrError::parse("wire", "non-utf8 string"))
}

fn put_f32_vec(out: &mut Vec<u8>, values: &[f32]) {
    put_u32(out, values.len() as u32);
    for &v in values {
        put_f32(out, v);
    }
}

fn get_f32_vec(bytes: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
    let len = get_u32(bytes, pos)? as usize;
    let s = take(bytes, pos, len * 4)?;
    Ok(s.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Encode one sparse payload with the cheapest lossless codec the PR-3
/// cost model picks for it: `[u32 dim][u8 codec][u32 len][codec bytes]`.
/// The payload bytes written equal the codec's exact cost function.
fn put_sparse(out: &mut Vec<u8>, v: &SparseVec, class: MessageClass) {
    let (codec, _) = CodecPolicy::lossless().pick(&v.indices, v.dim, class);
    let payload = codec.encode(v);
    put_u32(out, v.dim as u32);
    out.push(codec_tag(codec));
    put_u32(out, payload.len() as u32);
    out.extend_from_slice(&payload);
}

fn codec_tag(codec: WireCodec) -> u8 {
    match codec {
        WireCodec::DenseF32 => 0,
        WireCodec::SparseU32F32 => 1,
        WireCodec::DeltaVarintF16 => 2,
    }
}

fn codec_from_tag(tag: u8) -> Result<WireCodec> {
    match tag {
        0 => Ok(WireCodec::DenseF32),
        1 => Ok(WireCodec::SparseU32F32),
        2 => Ok(WireCodec::DeltaVarintF16),
        other => Err(DlrError::parse("wire", format!("unknown codec tag {other}"))),
    }
}

fn get_sparse(bytes: &[u8], pos: &mut usize) -> Result<SparseVec> {
    let dim = get_u32(bytes, pos)? as usize;
    let codec = codec_from_tag(get_u8(bytes, pos)?)?;
    let len = get_u32(bytes, pos)? as usize;
    let payload = take(bytes, pos, len)?;
    codec.decode(payload, dim)
}

// ---------------------------------------------------------------------------
// Message (en/de)coding
// ---------------------------------------------------------------------------

impl NodeMessage {
    /// Short name for logs and errors.
    pub fn name(&self) -> &'static str {
        match self {
            NodeMessage::Join { .. } => "join",
            NodeMessage::Welcome { .. } => "welcome",
            NodeMessage::Sweep { .. } => "sweep",
            NodeMessage::Swept { .. } => "swept",
            NodeMessage::Apply { .. } => "apply",
            NodeMessage::SetState { .. } => "set-state",
            NodeMessage::GetState => "get-state",
            NodeMessage::State { .. } => "state",
            NodeMessage::LambdaMax => "lambda-max",
            NodeMessage::LambdaMaxed { .. } => "lambda-maxed",
            NodeMessage::Margins { .. } => "margins",
            NodeMessage::MarginsPart { .. } => "margins-part",
            NodeMessage::Ping => "ping",
            NodeMessage::Pong => "pong",
            NodeMessage::Ack => "ack",
            NodeMessage::Abort { .. } => "abort",
            NodeMessage::Shutdown => "shutdown",
        }
    }

    /// Serialize into a frame body (`[tag][payload]`, no length prefix —
    /// the transport frames it).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            NodeMessage::Join {
                machine,
                n,
                p,
                local_features,
                cols_checksum,
                engine,
                family,
            } => {
                out.push(TAG_JOIN);
                put_u32(&mut out, *machine);
                put_u32(&mut out, *n);
                put_u32(&mut out, *p);
                put_u32(&mut out, *local_features);
                put_u64(&mut out, *cols_checksum);
                put_str(&mut out, engine);
                put_str(&mut out, family);
            }
            NodeMessage::Welcome { family, alpha } => {
                out.push(TAG_WELCOME);
                put_str(&mut out, family);
                put_f64(&mut out, *alpha);
            }
            NodeMessage::Sweep { lam, nu, l2, recycle: _ } => {
                // `recycle` is a buffer-recycling slot, not wire state
                out.push(TAG_SWEEP);
                put_f32(&mut out, *lam);
                put_f32(&mut out, *nu);
                put_f32(&mut out, *l2);
            }
            NodeMessage::Swept { result } => {
                out.push(TAG_SWEPT);
                put_sparse(&mut out, &result.delta_local, MessageClass::Beta);
                put_sparse(&mut out, &result.dmargins, MessageClass::Margins);
                put_f64(&mut out, result.compute_secs);
            }
            NodeMessage::Apply { alpha, dmargins, delta } => {
                out.push(TAG_APPLY);
                put_f32(&mut out, *alpha);
                put_sparse(&mut out, dmargins, MessageClass::Margins);
                match delta {
                    Some(d) => {
                        out.push(1);
                        put_sparse(&mut out, d, MessageClass::Beta);
                    }
                    None => out.push(0),
                }
            }
            NodeMessage::SetState { beta_local, margins } => {
                out.push(TAG_SET_STATE);
                put_f32_vec(&mut out, beta_local);
                put_f32_vec(&mut out, margins);
            }
            NodeMessage::GetState => out.push(TAG_GET_STATE),
            NodeMessage::State { beta_local, margins_crc } => {
                out.push(TAG_STATE);
                put_f32_vec(&mut out, beta_local);
                put_u64(&mut out, *margins_crc);
            }
            NodeMessage::LambdaMax => out.push(TAG_LAMBDA_MAX),
            NodeMessage::LambdaMaxed { value } => {
                out.push(TAG_LAMBDA_MAXED);
                put_f64(&mut out, *value);
            }
            NodeMessage::Margins { beta_local } => {
                out.push(TAG_MARGINS);
                put_f32_vec(&mut out, beta_local);
            }
            NodeMessage::MarginsPart { part } => {
                out.push(TAG_MARGINS_PART);
                put_sparse(&mut out, part, MessageClass::Margins);
            }
            NodeMessage::Ping => out.push(TAG_PING),
            NodeMessage::Pong => out.push(TAG_PONG),
            NodeMessage::Ack => out.push(TAG_ACK),
            NodeMessage::Abort { message } => {
                out.push(TAG_ABORT);
                put_str(&mut out, message);
            }
            NodeMessage::Shutdown => out.push(TAG_SHUTDOWN),
        }
        out
    }

    /// Deserialize a frame body. Truncated, oversized, or malformed frames
    /// return a `parse` error (never a panic) — same contract as the codec
    /// truncation tests.
    pub fn decode(bytes: &[u8]) -> Result<NodeMessage> {
        let mut pos = 0usize;
        let tag = get_u8(bytes, &mut pos)?;
        let msg = match tag {
            TAG_JOIN => NodeMessage::Join {
                machine: get_u32(bytes, &mut pos)?,
                n: get_u32(bytes, &mut pos)?,
                p: get_u32(bytes, &mut pos)?,
                local_features: get_u32(bytes, &mut pos)?,
                cols_checksum: get_u64(bytes, &mut pos)?,
                engine: get_str(bytes, &mut pos)?,
                family: get_str(bytes, &mut pos)?,
            },
            TAG_WELCOME => NodeMessage::Welcome {
                family: get_str(bytes, &mut pos)?,
                alpha: get_f64(bytes, &mut pos)?,
            },
            TAG_SWEEP => NodeMessage::Sweep {
                lam: get_f32(bytes, &mut pos)?,
                nu: get_f32(bytes, &mut pos)?,
                l2: get_f32(bytes, &mut pos)?,
                recycle: SweepResult::default(),
            },
            TAG_SWEPT => {
                let delta_local = get_sparse(bytes, &mut pos)?;
                let dmargins = get_sparse(bytes, &mut pos)?;
                let compute_secs = get_f64(bytes, &mut pos)?;
                NodeMessage::Swept {
                    result: SweepResult { delta_local, dmargins, compute_secs },
                }
            }
            TAG_APPLY => {
                let alpha = get_f32(bytes, &mut pos)?;
                let dmargins = Arc::new(get_sparse(bytes, &mut pos)?);
                let delta = match get_u8(bytes, &mut pos)? {
                    0 => None,
                    1 => Some(Arc::new(get_sparse(bytes, &mut pos)?)),
                    other => {
                        return Err(DlrError::parse(
                            "wire",
                            format!("bad option flag {other} in apply"),
                        ))
                    }
                };
                NodeMessage::Apply { alpha, dmargins, delta }
            }
            TAG_SET_STATE => NodeMessage::SetState {
                beta_local: get_f32_vec(bytes, &mut pos)?,
                margins: Arc::new(get_f32_vec(bytes, &mut pos)?),
            },
            TAG_GET_STATE => NodeMessage::GetState,
            TAG_STATE => NodeMessage::State {
                beta_local: get_f32_vec(bytes, &mut pos)?,
                margins_crc: get_u64(bytes, &mut pos)?,
            },
            TAG_LAMBDA_MAX => NodeMessage::LambdaMax,
            TAG_LAMBDA_MAXED => {
                NodeMessage::LambdaMaxed { value: get_f64(bytes, &mut pos)? }
            }
            TAG_MARGINS => NodeMessage::Margins { beta_local: get_f32_vec(bytes, &mut pos)? },
            TAG_MARGINS_PART => {
                NodeMessage::MarginsPart { part: get_sparse(bytes, &mut pos)? }
            }
            TAG_PING => NodeMessage::Ping,
            TAG_PONG => NodeMessage::Pong,
            TAG_ACK => NodeMessage::Ack,
            TAG_ABORT => NodeMessage::Abort { message: get_str(bytes, &mut pos)? },
            TAG_SHUTDOWN => NodeMessage::Shutdown,
            other => {
                return Err(DlrError::parse("wire", format!("unknown message tag {other}")))
            }
        };
        if pos != bytes.len() {
            return Err(DlrError::parse(
                "wire",
                format!("{} bytes of trailing garbage after {}", bytes.len() - pos, msg.name()),
            ));
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(dense: &[f32]) -> SparseVec {
        SparseVec::from_dense(dense)
    }

    #[test]
    fn every_message_round_trips() {
        let result = SweepResult {
            delta_local: sv(&[0.0, 1.5, 0.0, -2.25]),
            dmargins: sv(&[0.5, 0.0, -1.0]),
            compute_secs: 0.125,
        };
        let msgs = vec![
            NodeMessage::Join {
                machine: 3,
                n: 100,
                p: 40,
                local_features: 10,
                cols_checksum: 0xDEAD_BEEF,
                engine: "native".into(),
                family: "logistic".into(),
            },
            NodeMessage::Welcome { family: "poisson".into(), alpha: 0.5 },
            NodeMessage::Sweep {
                lam: 0.5,
                nu: 1e-6,
                l2: 0.25,
                recycle: SweepResult::default(),
            },
            NodeMessage::Swept { result },
            NodeMessage::Apply {
                alpha: 0.75,
                dmargins: Arc::new(sv(&[0.0, 2.0, 0.0])),
                delta: Some(Arc::new(sv(&[1.0, 0.0, 0.0, -3.5]))),
            },
            NodeMessage::Apply {
                alpha: 1.0,
                dmargins: Arc::new(sv(&[0.25, 0.0])),
                delta: None,
            },
            NodeMessage::SetState {
                beta_local: vec![1.0, -2.5e-8, 0.0],
                margins: Arc::new(vec![0.5, -0.0]),
            },
            NodeMessage::GetState,
            NodeMessage::State { beta_local: vec![3.25, 0.0], margins_crc: 42 },
            NodeMessage::LambdaMax,
            NodeMessage::LambdaMaxed { value: 0.1 + 0.2 },
            NodeMessage::Margins { beta_local: vec![0.5, -1.25, 0.0] },
            NodeMessage::MarginsPart { part: sv(&[0.0, 1.0, 0.0, -0.5]) },
            NodeMessage::Ping,
            NodeMessage::Pong,
            NodeMessage::Ack,
            NodeMessage::Abort { message: "worker exploded".into() },
            NodeMessage::Shutdown,
        ];
        for msg in msgs {
            let bytes = msg.encode();
            let back = NodeMessage::decode(&bytes)
                .unwrap_or_else(|e| panic!("{} failed to decode: {e}", msg.name()));
            assert_eq!(msg.name(), back.name());
            // field-level spot checks for the payload-carrying messages
            match (&msg, &back) {
                (
                    NodeMessage::Swept { result: a },
                    NodeMessage::Swept { result: b },
                ) => {
                    assert_eq!(a.delta_local, b.delta_local);
                    assert_eq!(a.dmargins, b.dmargins);
                    assert_eq!(a.compute_secs.to_bits(), b.compute_secs.to_bits());
                }
                (
                    NodeMessage::Apply { alpha: aa, dmargins: am, delta: ad },
                    NodeMessage::Apply { alpha: ba, dmargins: bm, delta: bd },
                ) => {
                    assert_eq!(aa.to_bits(), ba.to_bits());
                    assert_eq!(**am, **bm);
                    assert_eq!(ad.as_deref(), bd.as_deref());
                }
                (
                    NodeMessage::SetState { beta_local: ab, margins: am },
                    NodeMessage::SetState { beta_local: bb, margins: bm },
                ) => {
                    for (x, y) in ab.iter().zip(bb) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                    for (x, y) in am.iter().zip(bm.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (
                    NodeMessage::State { beta_local: ab, margins_crc: ac },
                    NodeMessage::State { beta_local: bb, margins_crc: bc },
                ) => {
                    assert_eq!(ab.len(), bb.len());
                    assert_eq!(ac, bc);
                }
                (
                    NodeMessage::Join { cols_checksum: a, engine: ae, family: af, .. },
                    NodeMessage::Join { cols_checksum: b, engine: be, family: bf, .. },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ae, be);
                    assert_eq!(af, bf);
                }
                (
                    NodeMessage::Welcome { family: af, alpha: aa },
                    NodeMessage::Welcome { family: bf, alpha: ba },
                ) => {
                    assert_eq!(af, bf);
                    assert_eq!(aa.to_bits(), ba.to_bits());
                }
                (
                    NodeMessage::Sweep { lam: al, nu: an, l2: a2, .. },
                    NodeMessage::Sweep { lam: bl, nu: bn, l2: b2, .. },
                ) => {
                    assert_eq!(al.to_bits(), bl.to_bits());
                    assert_eq!(an.to_bits(), bn.to_bits());
                    assert_eq!(a2.to_bits(), b2.to_bits());
                }
                (
                    NodeMessage::LambdaMaxed { value: a },
                    NodeMessage::LambdaMaxed { value: b },
                ) => assert_eq!(a.to_bits(), b.to_bits()),
                (
                    NodeMessage::Margins { beta_local: a },
                    NodeMessage::Margins { beta_local: b },
                ) => {
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (
                    NodeMessage::MarginsPart { part: a },
                    NodeMessage::MarginsPart { part: b },
                ) => assert_eq!(a, b),
                _ => {}
            }
        }
    }

    #[test]
    fn truncated_and_malformed_frames_error_cleanly() {
        let msg = NodeMessage::Swept {
            result: SweepResult {
                delta_local: sv(&[0.0, 1.0, 2.0]),
                dmargins: sv(&[3.0]),
                compute_secs: 1.0,
            },
        };
        let bytes = msg.encode();
        // every strict prefix must error, never panic
        for cut in 0..bytes.len() {
            assert!(NodeMessage::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        // trailing garbage is rejected, not ignored
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(NodeMessage::decode(&padded).is_err());
        // unknown tags are rejected
        assert!(NodeMessage::decode(&[99]).is_err());
        assert!(NodeMessage::decode(&[]).is_err());
        // a corrupt codec tag inside a sparse payload errors
        let mut bad = msg.encode();
        bad[1 + 4] = 7; // dim(u32) then codec tag of delta_local
        assert!(NodeMessage::decode(&bad).is_err());
    }

    #[test]
    fn sparse_payload_bytes_equal_codec_cost() {
        // the wire/ledger agreement: the payload section of an encoded
        // sparse message is exactly the codec cost the ledger would charge
        let msg = sv(&[0.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let (codec, cost) =
            CodecPolicy::lossless().pick(&msg.indices, msg.dim, MessageClass::Margins);
        let mut out = Vec::new();
        put_sparse(&mut out, &msg, MessageClass::Margins);
        // header = dim(4) + codec(1) + len(4)
        assert_eq!(out.len() as u64, 9 + cost);
        assert_eq!(codec.encoded_bytes(&msg), cost);
        let mut pos = 0;
        let back = get_sparse(&out, &mut pos).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn checksums_are_order_and_value_sensitive() {
        assert_ne!(crc_f32(&[1.0, 2.0]), crc_f32(&[2.0, 1.0]));
        assert_ne!(crc_f32(&[1.0]), crc_f32(&[1.0 + 1e-7]));
        assert_eq!(crc_f32(&[]), crc_f32(&[]));
        // -0.0 and 0.0 differ in bits, so they differ in crc (bit-exactness)
        assert_ne!(crc_f32(&[0.0]), crc_f32(&[-0.0]));
        assert_ne!(crc_u32(&[1, 2, 3]), crc_u32(&[1, 3, 2]));
    }
}
