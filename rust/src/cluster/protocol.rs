//! The serializable leader ↔ worker message protocol. Every interaction
//! with a cluster node — handshake, sweep requests, update application,
//! state push/pull, shutdown — is one [`NodeMessage`], so the same
//! `FitDriver` send/recv phases run unchanged over in-process channels and
//! over a real multi-process byte stream (see [`crate::cluster::transport`]).
//!
//! Sparse payloads are framed with the PR-3 wire codecs
//! ([`crate::cluster::codec`]): each message embeds the codec tag the
//! lossless byte-cost model picked, so under the default (lossless)
//! policy the bytes a [`SocketTransport`] actually writes for a Δ-payload
//! equal the codec cost functions the simulated `comm_bytes` ledger
//! charges per tree edge — the wire and the ledger agree byte-for-byte on
//! payload encoding.
//!
//! **Topology matrix.** The socket cluster routes collective traffic under
//! one of two physical topologies (`[cluster] topology = star | tree`):
//!
//! * **star** — every worker talks only to the leader; the leader stages
//!   all M sweep payloads and runs the tree merges itself. Leader
//!   bytes-on-wire grow O(M) per iteration.
//! * **tree** — [`NodeMessage::Welcome`] hands each worker a [`Topology`]
//!   (its bracket parent/children plus listen addresses); workers dial each
//!   other directly (shard-identity-validated [`NodeMessage::PeerHello`]
//!   handshake, mirroring the leader-join path) and relay `Sweep`/`Apply`
//!   down the physical tree while merging sweep results up it through the
//!   exact pairwise-f64 brackets of [`crate::cluster::allreduce`]
//!   ([`NodeMessage::TreeSwept`]). The leader touches only its O(1) root
//!   edge (machine 0) per iteration.
//!
//! **Bit-identity pins.** Both topologies and the in-process pool produce
//! bit-identical trajectories, β, and comm ledgers: the tree relays f64
//! merge intermediates exactly ([`TreePayload`] keeps raw f64 values on
//! interior edges whenever rounding would lose bits, and the bracket root
//! rounds to f32 exactly where the star-side engine does), and the leader
//! replays the per-edge ledger charges from nnz metadata carried up the
//! tree — the ledger already modeled tree edges, so it is unchanged. (With
//! the opt-in lossy `wire_f16_*` knobs the ledger charges the delta-varint
//! f16 cost while frames stay losslessly encoded; the tree topology
//! requires the default lossless policy, enforced at config validation.)
//!
//! [`SocketTransport`]: crate::cluster::transport::SocketTransport
//!
//! Malformed and truncated frames error exactly like the codec truncation
//! tests: every decode returns a `parse` error, never a panic and never a
//! silently-wrong value.

use std::sync::Arc;

use crate::cluster::codec::{CodecPolicy, MessageClass, WireCodec};
use crate::data::sparse::SparseVec;
use crate::engine::SweepResult;
use crate::error::{DlrError, Result};

/// Upper bound on one frame body — a guard against garbage length prefixes
/// from a rogue or corrupted peer, not a protocol limit.
pub const MAX_FRAME_BODY: usize = 1 << 30;

const TAG_JOIN: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_SWEEP: u8 = 3;
const TAG_SWEPT: u8 = 4;
const TAG_APPLY: u8 = 5;
const TAG_SET_STATE: u8 = 6;
const TAG_GET_STATE: u8 = 7;
const TAG_STATE: u8 = 8;
const TAG_ACK: u8 = 9;
const TAG_ABORT: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;
const TAG_LAMBDA_MAX: u8 = 12;
const TAG_LAMBDA_MAXED: u8 = 13;
const TAG_MARGINS: u8 = 14;
const TAG_MARGINS_PART: u8 = 15;
const TAG_PING: u8 = 16;
const TAG_PONG: u8 = 17;
const TAG_TOPOLOGY: u8 = 18;
const TAG_PEER_HELLO: u8 = 19;
const TAG_TREE_SWEPT: u8 = 20;

/// One peer a worker must link to under the tree topology: the machine
/// index it must identify as, the address its worker↔worker listener is
/// bound on, and the owned-column checksum its [`NodeMessage::PeerHello`]
/// must present (the same shard identity the leader validated at join).
#[derive(Debug, Clone, PartialEq)]
pub struct PeerInfo {
    pub machine: u32,
    pub addr: String,
    pub cols_checksum: u64,
}

/// A worker's view of the physical collective tree, handed out in
/// [`NodeMessage::Welcome`] at admission and re-issued as a standalone
/// [`NodeMessage::Topology`] after every supervised repair (replacements
/// listen on fresh addresses, so every worker rebuilds its peer links).
///
/// The tree is exactly the deterministic pairwise merge bracket of
/// [`crate::cluster::allreduce`]: `children` are listed in bracket round
/// order, which **is** the merge order — a worker folds child payloads
/// into its f64 accumulator in this order, so the physical tree reproduces
/// the leader-staged merges bit for bit. Machine 0 is always the bracket
/// root; its parent is the leader (`parent = None`).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Bumped by the leader on every (re-)issue; peers reject stale-epoch
    /// hellos so a link left over from a previous tree cannot be confused
    /// with a rebuilt one.
    pub epoch: u32,
    /// The worker's bracket parent, or `None` when the parent is the
    /// leader (machine 0 only).
    pub parent: Option<PeerInfo>,
    /// Bracket children in merge (round) order.
    pub children: Vec<PeerInfo>,
    /// Per-hop recv deadline for peer traffic, seconds; `0` = no deadline
    /// (mirrors the leader's `recv_timeout_secs`).
    pub peer_timeout_secs: f64,
}

/// One sparse payload relayed on a tree edge. Interior reduce edges carry
/// genuine f64 merge intermediates; to keep trajectories bit-identical to
/// the leader-staged engine the values are framed as f32 (the exact codec
/// framing the ledger charges) **iff every value round-trips f32 bit-for-
/// bit** — true by construction for merged Δβ (disjoint feature supports
/// only interleave) and for leaf/root Δm — and as raw f64 otherwise
/// (overlapping Δm sums on interior edges).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TreePayload {
    pub dim: u32,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl TreePayload {
    /// Is every value exactly representable as f32 (bit-level check, so
    /// `-0.0` survives)? Decides the f32-codec vs raw-f64 wire mode.
    pub fn is_f32_exact(&self) -> bool {
        self.values.iter().all(|v| ((*v as f32) as f64).to_bits() == v.to_bits())
    }

    /// Round to the f32 sparse vector the leader consumes — exactly the
    /// `v as f32` rounding the staged engine applies at the bracket root.
    pub fn to_sparse_f32(&self) -> SparseVec {
        let mut out = SparseVec::new(self.dim as usize);
        for (i, v) in self.indices.iter().zip(&self.values) {
            out.push(*i, *v as f32);
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }
}

/// Per-origin sweep metadata carried up the tree so the leader can pick
/// the exchange strategy and observe the byte estimators exactly as the
/// star path does (it needs every worker's raw contribution nnz).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OriginStat {
    pub machine: u32,
    pub compute_secs: f64,
    /// nnz of this worker's raw (pre-merge) global Δβ contribution.
    pub db_nnz: u32,
    /// nnz of this worker's raw (pre-merge) Δm contribution.
    pub dm_nnz: u32,
}

/// Per-edge merge metadata: the accumulated payload sizes worker `from`
/// shipped to worker `into`. The leader replays the bracket with these to
/// charge the ledger the identical per-edge costs the staged engine would.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeStat {
    pub into: u32,
    pub from: u32,
    /// nnz of the sender's accumulated Δβ at send time.
    pub db_nnz: u32,
    /// nnz of the sender's accumulated Δm at send time.
    pub dm_nnz: u32,
}

/// The merged sweep result a worker ships to its tree parent: its
/// subtree's merged Δβ (global ids) and Δm plus the origin/edge metadata
/// accumulated below it. Machine 0 sends the bracket root's f32-rounded
/// result to the leader.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TreeSwept {
    pub db: TreePayload,
    pub dm: TreePayload,
    pub origins: Vec<OriginStat>,
    pub edges: Vec<EdgeStat>,
}

impl Default for Topology {
    fn default() -> Self {
        Self { epoch: 0, parent: None, children: Vec::new(), peer_timeout_secs: 0.0 }
    }
}

/// One protocol message between the leader and a worker node.
///
/// Workers are *stateful* endpoints (see [`crate::cluster::node`]): they
/// hold their own β shard and margins, so a [`NodeMessage::Sweep`] carries
/// only the scalars of the subproblem and a [`NodeMessage::Apply`] carries
/// only the step size plus the merged Δmargins — the per-sweep
/// `beta_local` / `(w, z)` broadcasts of the pre-protocol `WorkerPool` are
/// gone entirely.
#[derive(Debug)]
pub enum NodeMessage {
    /// worker → leader: handshake. The leader validates the shard identity
    /// (machine index, dataset shape, owned-column checksum) and the GLM
    /// family the worker was configured with before admitting the node — a
    /// worker deriving (w, z) under a different family would silently
    /// corrupt the optimization.
    Join {
        machine: u32,
        n: u32,
        p: u32,
        local_features: u32,
        cols_checksum: u64,
        engine: String,
        family: String,
        /// Address of the worker's peer listener for tree-topology runs
        /// (workers dial each other from the [`Topology`] the leader hands
        /// out); empty when the worker runs star-only and binds none.
        listen_addr: String,
    },
    /// leader → worker: handshake accepted. Carries the run's GLM family
    /// and elastic-net α so a socket worker can double-check its own
    /// configuration against the leader's (the in-process pool constructs
    /// workers from the same `TrainConfig`, so its nodes skip the check),
    /// plus — under the tree topology — the worker's [`Topology`].
    Welcome { family: String, alpha: f64, topology: Option<Topology> },
    /// leader → worker: run one CD sweep over the worker-held shard state.
    /// `lam` is the soft-threshold (L1) strength λ·α and `l2` the ridge
    /// strength λ·(1−α) added to each coordinate's denominator (0 under the
    /// default pure-L1 configuration). `recycle` is an owned-buffer
    /// recycling slot for the in-process transport (the previous
    /// iteration's [`SweepResult`] buffers round trip so steady-state
    /// sweeps allocate nothing); it is *not* encoded on the wire — a socket
    /// worker fills a fresh default.
    Sweep { lam: f32, nu: f32, l2: f32, recycle: SweepResult },
    /// worker → leader: the sweep's sparse Δβ (shard-local ids) and Δm.
    Swept { result: SweepResult },
    /// leader → worker: line search picked `alpha`; apply `α·Δβ_local` to
    /// the worker-held β shard and `α·Δm` (the merged, post-codec
    /// Δmargins) to the worker-held margins. `delta` carries the merged
    /// global Δβ only when a lossy β wire is active (`wire_f16_beta`), so
    /// workers apply exactly what the leader applied; on the default
    /// lossless wire each worker's own Δβ already equals the merged values
    /// on its coordinates (disjoint feature partition) and nothing
    /// β-shaped needs to travel.
    Apply {
        alpha: f32,
        dmargins: Arc<SparseVec>,
        delta: Option<Arc<SparseVec>>,
    },
    /// leader → worker: install warmstart / resume state bit-for-bit.
    SetState {
        beta_local: Vec<f32>,
        margins: Arc<Vec<f32>>,
    },
    /// leader → worker: report the worker-held shard state (checkpointing).
    GetState,
    /// worker → leader: the shard state. Margins travel as a checksum — the
    /// leader only needs to *verify* sync, β travels in full for the
    /// checkpoint.
    State { beta_local: Vec<f32>, margins_crc: u64 },
    /// leader → worker: report this shard's λ_max contribution
    /// `max_j |Σ_i x_ij t_i| · scale` over its own features (targets `t`
    /// and `scale` come from the node's GLM family; logistic: `t = y`,
    /// `scale = 1/2`) — part of the
    /// distributed reduce that lets an out-of-core leader find λ_max
    /// without ever holding X (each per-feature f64 sum is bit-identical
    /// to the in-memory scan; the max over disjoint shards is exact).
    LambdaMax,
    /// worker → leader: the shard's λ_max contribution.
    LambdaMaxed { value: f64 },
    /// leader → worker: compute the shard's margins product
    /// `Σ_{j ∈ shard} β_j x_ij` for the given shard-local β — the
    /// distributed warmstart install. Stateless: the node's own (β,
    /// margins) are untouched (the leader follows up with a `SetState`).
    Margins { beta_local: Vec<f32> },
    /// worker → leader: the shard's sparse margins contribution.
    MarginsPart { part: SparseVec },
    /// leader → worker: liveness probe. A healthy node answers
    /// [`NodeMessage::Pong`] immediately; the supervisor uses the
    /// ping/pong pair (under a recv deadline) both to detect wedged
    /// workers and to drain at most one stale reply left on a link by a
    /// failed phase — the protocol is strictly request/reply, so one
    /// un-consumed message is the worst case.
    Ping,
    /// worker → leader: the heartbeat answer.
    Pong,
    /// leader → worker: a fresh tree [`Topology`] (after a supervised
    /// repair re-admitted a replacement on a new listen address). The
    /// worker drops every peer link and rebuilds from this view; the
    /// bumped epoch fences out connections from the previous tree.
    Topology(Topology),
    /// worker → worker: peer-link handshake, the tree-edge mirror of
    /// [`NodeMessage::Join`]. The accepting parent validates the machine
    /// index, the epoch, and the owned-column checksum against the
    /// [`PeerInfo`] in its own topology before acking the link.
    PeerHello { machine: u32, epoch: u32, cols_checksum: u64 },
    /// worker → {parent worker | leader}: the subtree's merged sweep
    /// result plus replay metadata (tree topology's up-path framing).
    TreeSwept(TreeSwept),
    /// worker → leader: acknowledgement of an `Apply` / `SetState`.
    Ack,
    /// either direction: the peer failed; the message is the error.
    Abort { message: String },
    /// leader → worker: clean shutdown, the serve loop exits.
    Shutdown,
}

/// An [`NodeMessage::Abort`] is last-words courtesy to a peer that may
/// already be gone, so its send failing is expected — but it must never be
/// *silently* swallowed: a peer that misses the abort will sit blocked
/// until its own read fails. Every abort-send site routes through here so
/// the loss is logged once, with the machine id and the phase it happened
/// in.
pub(crate) fn log_lost_abort(
    machine: usize,
    context: &str,
    err: &dyn std::fmt::Display,
) {
    eprintln!(
        "[cluster] could not deliver abort to worker {machine} during {context}: {err}"
    );
}

// ---------------------------------------------------------------------------
// Checksums (FNV-1a — cheap, deterministic, dependency-free)
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over the f32 bit patterns — the margins-sync check of
/// [`NodeMessage::State`].
pub fn crc_f32(values: &[f32]) -> u64 {
    values.iter().fold(FNV_OFFSET, |h, v| fnv1a(h, &v.to_bits().to_le_bytes()))
}

/// FNV-1a over u32 little-endian bytes — the owned-column identity check of
/// [`NodeMessage::Join`].
pub fn crc_u32(values: &[u32]) -> u64 {
    values.iter().fold(FNV_OFFSET, |h, v| fnv1a(h, &v.to_le_bytes()))
}

// ---------------------------------------------------------------------------
// Primitive (en/de)coders
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn take<'a>(bytes: &'a [u8], pos: &mut usize, len: usize) -> Result<&'a [u8]> {
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| DlrError::parse("wire", "truncated frame"))?;
    let s = &bytes[*pos..end];
    *pos = end;
    Ok(s)
}

fn get_u8(bytes: &[u8], pos: &mut usize) -> Result<u8> {
    Ok(take(bytes, pos, 1)?[0])
}

fn get_u32(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let s = take(bytes, pos, 4)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

fn get_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let s = take(bytes, pos, 8)?;
    Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
}

fn get_f32(bytes: &[u8], pos: &mut usize) -> Result<f32> {
    Ok(f32::from_bits(get_u32(bytes, pos)?))
}

fn get_f64(bytes: &[u8], pos: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(get_u64(bytes, pos)?))
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_u32(bytes, pos)? as usize;
    let s = take(bytes, pos, len)?;
    String::from_utf8(s.to_vec()).map_err(|_| DlrError::parse("wire", "non-utf8 string"))
}

fn put_f32_vec(out: &mut Vec<u8>, values: &[f32]) {
    put_u32(out, values.len() as u32);
    for &v in values {
        put_f32(out, v);
    }
}

fn get_f32_vec(bytes: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
    let len = get_u32(bytes, pos)? as usize;
    let s = take(bytes, pos, len * 4)?;
    Ok(s.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Encode one sparse payload with the cheapest lossless codec the PR-3
/// cost model picks for it: `[u32 dim][u8 codec][u32 len][codec bytes]`.
/// The payload bytes written equal the codec's exact cost function.
fn put_sparse(out: &mut Vec<u8>, v: &SparseVec, class: MessageClass) {
    let (codec, _) = CodecPolicy::lossless().pick(&v.indices, v.dim, class);
    let payload = codec.encode(v);
    put_u32(out, v.dim as u32);
    out.push(codec_tag(codec));
    put_u32(out, payload.len() as u32);
    out.extend_from_slice(&payload);
}

fn codec_tag(codec: WireCodec) -> u8 {
    match codec {
        WireCodec::DenseF32 => 0,
        WireCodec::SparseU32F32 => 1,
        WireCodec::DeltaVarintF16 => 2,
    }
}

fn codec_from_tag(tag: u8) -> Result<WireCodec> {
    match tag {
        0 => Ok(WireCodec::DenseF32),
        1 => Ok(WireCodec::SparseU32F32),
        2 => Ok(WireCodec::DeltaVarintF16),
        other => Err(DlrError::parse("wire", format!("unknown codec tag {other}"))),
    }
}

fn get_sparse(bytes: &[u8], pos: &mut usize) -> Result<SparseVec> {
    let dim = get_u32(bytes, pos)? as usize;
    let codec = codec_from_tag(get_u8(bytes, pos)?)?;
    let len = get_u32(bytes, pos)? as usize;
    let payload = take(bytes, pos, len)?;
    codec.decode(payload, dim)
}

/// Tree-edge payload framing: mode byte `0` = f32 codec framing (the exact
/// [`put_sparse`] section the ledger's cost functions describe — legal only
/// when every value is f32-bit-exact), mode `1` = raw `(u32 idx, f64 val)`
/// pairs for genuine f64 merge intermediates.
fn put_tree_payload(out: &mut Vec<u8>, p: &TreePayload, class: MessageClass) {
    if p.is_f32_exact() {
        out.push(0);
        put_sparse(out, &p.to_sparse_f32(), class);
    } else {
        out.push(1);
        put_u32(out, p.dim);
        put_u32(out, p.indices.len() as u32);
        for &i in &p.indices {
            put_u32(out, i);
        }
        for &v in &p.values {
            put_f64(out, v);
        }
    }
}

fn get_tree_payload(bytes: &[u8], pos: &mut usize) -> Result<TreePayload> {
    match get_u8(bytes, pos)? {
        0 => {
            let sv = get_sparse(bytes, pos)?;
            Ok(TreePayload {
                dim: sv.dim as u32,
                values: sv.values.iter().map(|&v| v as f64).collect(),
                indices: sv.indices,
            })
        }
        1 => {
            let dim = get_u32(bytes, pos)?;
            let len = get_u32(bytes, pos)? as usize;
            // bounds-check the whole section before allocating (a lying
            // length prefix must error, not trigger a giant allocation)
            let idx_bytes = take(bytes, pos, len.checked_mul(4).unwrap_or(usize::MAX))?;
            let mut indices = Vec::with_capacity(len);
            for c in idx_bytes.chunks_exact(4) {
                let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                if i >= dim {
                    return Err(DlrError::parse("wire", format!("index {i} >= dim {dim}")));
                }
                if indices.last().is_some_and(|&last| last >= i) {
                    return Err(DlrError::parse("wire", "indices not strictly ascending"));
                }
                indices.push(i);
            }
            let val_bytes = take(bytes, pos, len.checked_mul(8).unwrap_or(usize::MAX))?;
            let values = val_bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                .collect();
            Ok(TreePayload { dim, indices, values })
        }
        other => Err(DlrError::parse("wire", format!("bad tree payload mode {other}"))),
    }
}

fn put_peer_info(out: &mut Vec<u8>, p: &PeerInfo) {
    put_u32(out, p.machine);
    put_str(out, &p.addr);
    put_u64(out, p.cols_checksum);
}

fn get_peer_info(bytes: &[u8], pos: &mut usize) -> Result<PeerInfo> {
    Ok(PeerInfo {
        machine: get_u32(bytes, pos)?,
        addr: get_str(bytes, pos)?,
        cols_checksum: get_u64(bytes, pos)?,
    })
}

fn put_topology(out: &mut Vec<u8>, t: &Topology) {
    put_u32(out, t.epoch);
    match &t.parent {
        Some(p) => {
            out.push(1);
            put_peer_info(out, p);
        }
        None => out.push(0),
    }
    put_u32(out, t.children.len() as u32);
    for c in &t.children {
        put_peer_info(out, c);
    }
    put_f64(out, t.peer_timeout_secs);
}

fn get_topology(bytes: &[u8], pos: &mut usize) -> Result<Topology> {
    let epoch = get_u32(bytes, pos)?;
    let parent = match get_u8(bytes, pos)? {
        0 => None,
        1 => Some(get_peer_info(bytes, pos)?),
        other => {
            return Err(DlrError::parse("wire", format!("bad option flag {other} in topology")))
        }
    };
    let n_children = get_u32(bytes, pos)? as usize;
    let mut children = Vec::with_capacity(n_children.min((bytes.len() - *pos) / 16));
    for _ in 0..n_children {
        children.push(get_peer_info(bytes, pos)?);
    }
    let peer_timeout_secs = get_f64(bytes, pos)?;
    Ok(Topology { epoch, parent, children, peer_timeout_secs })
}

fn put_tree_swept(out: &mut Vec<u8>, t: &TreeSwept) {
    put_tree_payload(out, &t.db, MessageClass::Beta);
    put_tree_payload(out, &t.dm, MessageClass::Margins);
    put_u32(out, t.origins.len() as u32);
    for o in &t.origins {
        put_u32(out, o.machine);
        put_f64(out, o.compute_secs);
        put_u32(out, o.db_nnz);
        put_u32(out, o.dm_nnz);
    }
    put_u32(out, t.edges.len() as u32);
    for e in &t.edges {
        put_u32(out, e.into);
        put_u32(out, e.from);
        put_u32(out, e.db_nnz);
        put_u32(out, e.dm_nnz);
    }
}

fn get_tree_swept(bytes: &[u8], pos: &mut usize) -> Result<TreeSwept> {
    let db = get_tree_payload(bytes, pos)?;
    let dm = get_tree_payload(bytes, pos)?;
    let n_origins = get_u32(bytes, pos)? as usize;
    let mut origins = Vec::with_capacity(n_origins.min((bytes.len() - *pos) / 20));
    for _ in 0..n_origins {
        origins.push(OriginStat {
            machine: get_u32(bytes, pos)?,
            compute_secs: get_f64(bytes, pos)?,
            db_nnz: get_u32(bytes, pos)?,
            dm_nnz: get_u32(bytes, pos)?,
        });
    }
    let n_edges = get_u32(bytes, pos)? as usize;
    let mut edges = Vec::with_capacity(n_edges.min((bytes.len() - *pos) / 16));
    for _ in 0..n_edges {
        edges.push(EdgeStat {
            into: get_u32(bytes, pos)?,
            from: get_u32(bytes, pos)?,
            db_nnz: get_u32(bytes, pos)?,
            dm_nnz: get_u32(bytes, pos)?,
        });
    }
    Ok(TreeSwept { db, dm, origins, edges })
}

// ---------------------------------------------------------------------------
// Message (en/de)coding
// ---------------------------------------------------------------------------

impl NodeMessage {
    /// Short name for logs and errors.
    pub fn name(&self) -> &'static str {
        match self {
            NodeMessage::Join { .. } => "join",
            NodeMessage::Welcome { .. } => "welcome",
            NodeMessage::Sweep { .. } => "sweep",
            NodeMessage::Swept { .. } => "swept",
            NodeMessage::Apply { .. } => "apply",
            NodeMessage::SetState { .. } => "set-state",
            NodeMessage::GetState => "get-state",
            NodeMessage::State { .. } => "state",
            NodeMessage::LambdaMax => "lambda-max",
            NodeMessage::LambdaMaxed { .. } => "lambda-maxed",
            NodeMessage::Margins { .. } => "margins",
            NodeMessage::MarginsPart { .. } => "margins-part",
            NodeMessage::Ping => "ping",
            NodeMessage::Pong => "pong",
            NodeMessage::Topology(_) => "topology",
            NodeMessage::PeerHello { .. } => "peer-hello",
            NodeMessage::TreeSwept(_) => "tree-swept",
            NodeMessage::Ack => "ack",
            NodeMessage::Abort { .. } => "abort",
            NodeMessage::Shutdown => "shutdown",
        }
    }

    /// Serialize into a frame body (`[tag][payload]`, no length prefix —
    /// the transport frames it).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            NodeMessage::Join {
                machine,
                n,
                p,
                local_features,
                cols_checksum,
                engine,
                family,
                listen_addr,
            } => {
                out.push(TAG_JOIN);
                put_u32(&mut out, *machine);
                put_u32(&mut out, *n);
                put_u32(&mut out, *p);
                put_u32(&mut out, *local_features);
                put_u64(&mut out, *cols_checksum);
                put_str(&mut out, engine);
                put_str(&mut out, family);
                put_str(&mut out, listen_addr);
            }
            NodeMessage::Welcome { family, alpha, topology } => {
                out.push(TAG_WELCOME);
                put_str(&mut out, family);
                put_f64(&mut out, *alpha);
                match topology {
                    Some(t) => {
                        out.push(1);
                        put_topology(&mut out, t);
                    }
                    None => out.push(0),
                }
            }
            NodeMessage::Sweep { lam, nu, l2, recycle: _ } => {
                // `recycle` is a buffer-recycling slot, not wire state
                out.push(TAG_SWEEP);
                put_f32(&mut out, *lam);
                put_f32(&mut out, *nu);
                put_f32(&mut out, *l2);
            }
            NodeMessage::Swept { result } => {
                out.push(TAG_SWEPT);
                put_sparse(&mut out, &result.delta_local, MessageClass::Beta);
                put_sparse(&mut out, &result.dmargins, MessageClass::Margins);
                put_f64(&mut out, result.compute_secs);
            }
            NodeMessage::Apply { alpha, dmargins, delta } => {
                out.push(TAG_APPLY);
                put_f32(&mut out, *alpha);
                put_sparse(&mut out, dmargins, MessageClass::Margins);
                match delta {
                    Some(d) => {
                        out.push(1);
                        put_sparse(&mut out, d, MessageClass::Beta);
                    }
                    None => out.push(0),
                }
            }
            NodeMessage::SetState { beta_local, margins } => {
                out.push(TAG_SET_STATE);
                put_f32_vec(&mut out, beta_local);
                put_f32_vec(&mut out, margins);
            }
            NodeMessage::GetState => out.push(TAG_GET_STATE),
            NodeMessage::State { beta_local, margins_crc } => {
                out.push(TAG_STATE);
                put_f32_vec(&mut out, beta_local);
                put_u64(&mut out, *margins_crc);
            }
            NodeMessage::LambdaMax => out.push(TAG_LAMBDA_MAX),
            NodeMessage::LambdaMaxed { value } => {
                out.push(TAG_LAMBDA_MAXED);
                put_f64(&mut out, *value);
            }
            NodeMessage::Margins { beta_local } => {
                out.push(TAG_MARGINS);
                put_f32_vec(&mut out, beta_local);
            }
            NodeMessage::MarginsPart { part } => {
                out.push(TAG_MARGINS_PART);
                put_sparse(&mut out, part, MessageClass::Margins);
            }
            NodeMessage::Ping => out.push(TAG_PING),
            NodeMessage::Pong => out.push(TAG_PONG),
            NodeMessage::Topology(t) => {
                out.push(TAG_TOPOLOGY);
                put_topology(&mut out, t);
            }
            NodeMessage::PeerHello { machine, epoch, cols_checksum } => {
                out.push(TAG_PEER_HELLO);
                put_u32(&mut out, *machine);
                put_u32(&mut out, *epoch);
                put_u64(&mut out, *cols_checksum);
            }
            NodeMessage::TreeSwept(t) => {
                out.push(TAG_TREE_SWEPT);
                put_tree_swept(&mut out, t);
            }
            NodeMessage::Ack => out.push(TAG_ACK),
            NodeMessage::Abort { message } => {
                out.push(TAG_ABORT);
                put_str(&mut out, message);
            }
            NodeMessage::Shutdown => out.push(TAG_SHUTDOWN),
        }
        out
    }

    /// Deserialize a frame body. Truncated, oversized, or malformed frames
    /// return a `parse` error (never a panic) — same contract as the codec
    /// truncation tests.
    pub fn decode(bytes: &[u8]) -> Result<NodeMessage> {
        let mut pos = 0usize;
        let tag = get_u8(bytes, &mut pos)?;
        let msg = match tag {
            TAG_JOIN => NodeMessage::Join {
                machine: get_u32(bytes, &mut pos)?,
                n: get_u32(bytes, &mut pos)?,
                p: get_u32(bytes, &mut pos)?,
                local_features: get_u32(bytes, &mut pos)?,
                cols_checksum: get_u64(bytes, &mut pos)?,
                engine: get_str(bytes, &mut pos)?,
                family: get_str(bytes, &mut pos)?,
                listen_addr: get_str(bytes, &mut pos)?,
            },
            TAG_WELCOME => NodeMessage::Welcome {
                family: get_str(bytes, &mut pos)?,
                alpha: get_f64(bytes, &mut pos)?,
                topology: match get_u8(bytes, &mut pos)? {
                    0 => None,
                    1 => Some(get_topology(bytes, &mut pos)?),
                    other => {
                        return Err(DlrError::parse(
                            "wire",
                            format!("bad option flag {other} in welcome"),
                        ))
                    }
                },
            },
            TAG_SWEEP => NodeMessage::Sweep {
                lam: get_f32(bytes, &mut pos)?,
                nu: get_f32(bytes, &mut pos)?,
                l2: get_f32(bytes, &mut pos)?,
                recycle: SweepResult::default(),
            },
            TAG_SWEPT => {
                let delta_local = get_sparse(bytes, &mut pos)?;
                let dmargins = get_sparse(bytes, &mut pos)?;
                let compute_secs = get_f64(bytes, &mut pos)?;
                NodeMessage::Swept {
                    result: SweepResult { delta_local, dmargins, compute_secs },
                }
            }
            TAG_APPLY => {
                let alpha = get_f32(bytes, &mut pos)?;
                let dmargins = Arc::new(get_sparse(bytes, &mut pos)?);
                let delta = match get_u8(bytes, &mut pos)? {
                    0 => None,
                    1 => Some(Arc::new(get_sparse(bytes, &mut pos)?)),
                    other => {
                        return Err(DlrError::parse(
                            "wire",
                            format!("bad option flag {other} in apply"),
                        ))
                    }
                };
                NodeMessage::Apply { alpha, dmargins, delta }
            }
            TAG_SET_STATE => NodeMessage::SetState {
                beta_local: get_f32_vec(bytes, &mut pos)?,
                margins: Arc::new(get_f32_vec(bytes, &mut pos)?),
            },
            TAG_GET_STATE => NodeMessage::GetState,
            TAG_STATE => NodeMessage::State {
                beta_local: get_f32_vec(bytes, &mut pos)?,
                margins_crc: get_u64(bytes, &mut pos)?,
            },
            TAG_LAMBDA_MAX => NodeMessage::LambdaMax,
            TAG_LAMBDA_MAXED => {
                NodeMessage::LambdaMaxed { value: get_f64(bytes, &mut pos)? }
            }
            TAG_MARGINS => NodeMessage::Margins { beta_local: get_f32_vec(bytes, &mut pos)? },
            TAG_MARGINS_PART => {
                NodeMessage::MarginsPart { part: get_sparse(bytes, &mut pos)? }
            }
            TAG_PING => NodeMessage::Ping,
            TAG_PONG => NodeMessage::Pong,
            TAG_TOPOLOGY => NodeMessage::Topology(get_topology(bytes, &mut pos)?),
            TAG_PEER_HELLO => NodeMessage::PeerHello {
                machine: get_u32(bytes, &mut pos)?,
                epoch: get_u32(bytes, &mut pos)?,
                cols_checksum: get_u64(bytes, &mut pos)?,
            },
            TAG_TREE_SWEPT => NodeMessage::TreeSwept(get_tree_swept(bytes, &mut pos)?),
            TAG_ACK => NodeMessage::Ack,
            TAG_ABORT => NodeMessage::Abort { message: get_str(bytes, &mut pos)? },
            TAG_SHUTDOWN => NodeMessage::Shutdown,
            other => {
                return Err(DlrError::parse("wire", format!("unknown message tag {other}")))
            }
        };
        if pos != bytes.len() {
            return Err(DlrError::parse(
                "wire",
                format!("{} bytes of trailing garbage after {}", bytes.len() - pos, msg.name()),
            ));
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(dense: &[f32]) -> SparseVec {
        SparseVec::from_dense(dense)
    }

    #[test]
    fn every_message_round_trips() {
        let result = SweepResult {
            delta_local: sv(&[0.0, 1.5, 0.0, -2.25]),
            dmargins: sv(&[0.5, 0.0, -1.0]),
            compute_secs: 0.125,
        };
        let msgs = vec![
            NodeMessage::Join {
                machine: 3,
                n: 100,
                p: 40,
                local_features: 10,
                cols_checksum: 0xDEAD_BEEF,
                engine: "native".into(),
                family: "logistic".into(),
                listen_addr: "127.0.0.1:40123".into(),
            },
            NodeMessage::Welcome { family: "poisson".into(), alpha: 0.5, topology: None },
            NodeMessage::Welcome {
                family: "logistic".into(),
                alpha: 1.0,
                topology: Some(Topology {
                    epoch: 2,
                    parent: Some(PeerInfo {
                        machine: 0,
                        addr: "127.0.0.1:41000".into(),
                        cols_checksum: 7,
                    }),
                    children: vec![PeerInfo {
                        machine: 3,
                        addr: "127.0.0.1:41003".into(),
                        cols_checksum: 9,
                    }],
                    peer_timeout_secs: 2.5,
                }),
            },
            NodeMessage::Topology(Topology {
                epoch: 5,
                parent: None,
                children: vec![
                    PeerInfo { machine: 1, addr: "a:1".into(), cols_checksum: 1 },
                    PeerInfo { machine: 2, addr: "b:2".into(), cols_checksum: 2 },
                ],
                peer_timeout_secs: 0.0,
            }),
            NodeMessage::PeerHello { machine: 6, epoch: 3, cols_checksum: 0xFEED },
            NodeMessage::TreeSwept(TreeSwept {
                db: TreePayload { dim: 40, indices: vec![1, 7], values: vec![0.5, -2.25] },
                dm: TreePayload {
                    dim: 100,
                    indices: vec![0, 3, 9],
                    // middle value is NOT f32-exact: forces the raw-f64 mode
                    values: vec![1.0, 0.1f64 + 0.2f64, -0.5],
                },
                origins: vec![
                    OriginStat { machine: 1, compute_secs: 0.25, db_nnz: 2, dm_nnz: 3 },
                    OriginStat { machine: 3, compute_secs: 0.5, db_nnz: 0, dm_nnz: 1 },
                ],
                edges: vec![EdgeStat { into: 1, from: 3, db_nnz: 2, dm_nnz: 3 }],
            }),
            NodeMessage::Sweep {
                lam: 0.5,
                nu: 1e-6,
                l2: 0.25,
                recycle: SweepResult::default(),
            },
            NodeMessage::Swept { result },
            NodeMessage::Apply {
                alpha: 0.75,
                dmargins: Arc::new(sv(&[0.0, 2.0, 0.0])),
                delta: Some(Arc::new(sv(&[1.0, 0.0, 0.0, -3.5]))),
            },
            NodeMessage::Apply {
                alpha: 1.0,
                dmargins: Arc::new(sv(&[0.25, 0.0])),
                delta: None,
            },
            NodeMessage::SetState {
                beta_local: vec![1.0, -2.5e-8, 0.0],
                margins: Arc::new(vec![0.5, -0.0]),
            },
            NodeMessage::GetState,
            NodeMessage::State { beta_local: vec![3.25, 0.0], margins_crc: 42 },
            NodeMessage::LambdaMax,
            NodeMessage::LambdaMaxed { value: 0.1 + 0.2 },
            NodeMessage::Margins { beta_local: vec![0.5, -1.25, 0.0] },
            NodeMessage::MarginsPart { part: sv(&[0.0, 1.0, 0.0, -0.5]) },
            NodeMessage::Ping,
            NodeMessage::Pong,
            NodeMessage::Ack,
            NodeMessage::Abort { message: "worker exploded".into() },
            NodeMessage::Shutdown,
        ];
        for msg in msgs {
            let bytes = msg.encode();
            let back = NodeMessage::decode(&bytes)
                .unwrap_or_else(|e| panic!("{} failed to decode: {e}", msg.name()));
            assert_eq!(msg.name(), back.name());
            // field-level spot checks for the payload-carrying messages
            match (&msg, &back) {
                (
                    NodeMessage::Swept { result: a },
                    NodeMessage::Swept { result: b },
                ) => {
                    assert_eq!(a.delta_local, b.delta_local);
                    assert_eq!(a.dmargins, b.dmargins);
                    assert_eq!(a.compute_secs.to_bits(), b.compute_secs.to_bits());
                }
                (
                    NodeMessage::Apply { alpha: aa, dmargins: am, delta: ad },
                    NodeMessage::Apply { alpha: ba, dmargins: bm, delta: bd },
                ) => {
                    assert_eq!(aa.to_bits(), ba.to_bits());
                    assert_eq!(**am, **bm);
                    assert_eq!(ad.as_deref(), bd.as_deref());
                }
                (
                    NodeMessage::SetState { beta_local: ab, margins: am },
                    NodeMessage::SetState { beta_local: bb, margins: bm },
                ) => {
                    for (x, y) in ab.iter().zip(bb) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                    for (x, y) in am.iter().zip(bm.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (
                    NodeMessage::State { beta_local: ab, margins_crc: ac },
                    NodeMessage::State { beta_local: bb, margins_crc: bc },
                ) => {
                    assert_eq!(ab.len(), bb.len());
                    assert_eq!(ac, bc);
                }
                (
                    NodeMessage::Join { cols_checksum: a, engine: ae, family: af, .. },
                    NodeMessage::Join { cols_checksum: b, engine: be, family: bf, .. },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ae, be);
                    assert_eq!(af, bf);
                }
                (
                    NodeMessage::Welcome { family: af, alpha: aa, topology: at },
                    NodeMessage::Welcome { family: bf, alpha: ba, topology: bt },
                ) => {
                    assert_eq!(af, bf);
                    assert_eq!(aa.to_bits(), ba.to_bits());
                    assert_eq!(at, bt);
                }
                (NodeMessage::Topology(a), NodeMessage::Topology(b)) => assert_eq!(a, b),
                (
                    NodeMessage::PeerHello { machine: am, epoch: ae, cols_checksum: ac },
                    NodeMessage::PeerHello { machine: bm, epoch: be, cols_checksum: bc },
                ) => {
                    assert_eq!((am, ae, ac), (bm, be, bc));
                }
                (NodeMessage::TreeSwept(a), NodeMessage::TreeSwept(b)) => {
                    assert_eq!(a.db, b.db);
                    for (x, y) in a.dm.values.iter().zip(&b.dm.values) {
                        assert_eq!(x.to_bits(), y.to_bits(), "dm values must survive bit-exactly");
                    }
                    assert_eq!(a.dm.indices, b.dm.indices);
                    assert_eq!(a.origins, b.origins);
                    assert_eq!(a.edges, b.edges);
                }
                (
                    NodeMessage::Sweep { lam: al, nu: an, l2: a2, .. },
                    NodeMessage::Sweep { lam: bl, nu: bn, l2: b2, .. },
                ) => {
                    assert_eq!(al.to_bits(), bl.to_bits());
                    assert_eq!(an.to_bits(), bn.to_bits());
                    assert_eq!(a2.to_bits(), b2.to_bits());
                }
                (
                    NodeMessage::LambdaMaxed { value: a },
                    NodeMessage::LambdaMaxed { value: b },
                ) => assert_eq!(a.to_bits(), b.to_bits()),
                (
                    NodeMessage::Margins { beta_local: a },
                    NodeMessage::Margins { beta_local: b },
                ) => {
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (
                    NodeMessage::MarginsPart { part: a },
                    NodeMessage::MarginsPart { part: b },
                ) => assert_eq!(a, b),
                _ => {}
            }
        }
    }

    #[test]
    fn truncated_and_malformed_frames_error_cleanly() {
        let msg = NodeMessage::Swept {
            result: SweepResult {
                delta_local: sv(&[0.0, 1.0, 2.0]),
                dmargins: sv(&[3.0]),
                compute_secs: 1.0,
            },
        };
        let bytes = msg.encode();
        // every strict prefix must error, never panic
        for cut in 0..bytes.len() {
            assert!(NodeMessage::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        // trailing garbage is rejected, not ignored
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(NodeMessage::decode(&padded).is_err());
        // unknown tags are rejected
        assert!(NodeMessage::decode(&[99]).is_err());
        assert!(NodeMessage::decode(&[]).is_err());
        // a corrupt codec tag inside a sparse payload errors
        let mut bad = msg.encode();
        bad[1 + 4] = 7; // dim(u32) then codec tag of delta_local
        assert!(NodeMessage::decode(&bad).is_err());
    }

    #[test]
    fn sparse_payload_bytes_equal_codec_cost() {
        // the wire/ledger agreement: the payload section of an encoded
        // sparse message is exactly the codec cost the ledger would charge
        let msg = sv(&[0.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let (codec, cost) =
            CodecPolicy::lossless().pick(&msg.indices, msg.dim, MessageClass::Margins);
        let mut out = Vec::new();
        put_sparse(&mut out, &msg, MessageClass::Margins);
        // header = dim(4) + codec(1) + len(4)
        assert_eq!(out.len() as u64, 9 + cost);
        assert_eq!(codec.encoded_bytes(&msg), cost);
        let mut pos = 0;
        let back = get_sparse(&out, &mut pos).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn tree_payload_picks_f32_framing_iff_values_are_exact() {
        // f32-exact values (every merged Δβ, every leaf/root Δm): the wire
        // section is the codec framing whose payload bytes equal the
        // ledger's charged cost function — mode byte + [dim|codec|len|payload]
        let exact = TreePayload {
            dim: 1_000,
            indices: vec![3, 17, 512],
            values: vec![1.5, -0.25, 2.0f32 as f64],
        };
        assert!(exact.is_f32_exact());
        let mut out = Vec::new();
        put_tree_payload(&mut out, &exact, MessageClass::Beta);
        let sv = exact.to_sparse_f32();
        let (_, cost) = CodecPolicy::lossless().pick(&sv.indices, sv.dim, MessageClass::Beta);
        assert_eq!(out.len() as u64, 1 + 9 + cost, "mode0 payload bytes = charged cost");
        let mut pos = 0;
        let back = get_tree_payload(&out, &mut pos).unwrap();
        assert_eq!(back, exact);

        // a genuine f64 merge intermediate keeps every bit through the wire
        let inexact = TreePayload {
            dim: 10,
            indices: vec![2, 5],
            values: vec![0.1 + 0.2, 1.0],
        };
        assert!(!inexact.is_f32_exact());
        let mut out = Vec::new();
        put_tree_payload(&mut out, &inexact, MessageClass::Margins);
        assert_eq!(out[0], 1, "overlapping f64 sums must use the raw mode");
        let mut pos = 0;
        let back = get_tree_payload(&out, &mut pos).unwrap();
        for (x, y) in back.values.iter().zip(&inexact.values) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // -0.0 is not "exactly representable as 0.0": the bit check keeps it
        let signed_zero =
            TreePayload { dim: 4, indices: vec![1], values: vec![-0.0f64] };
        assert!(signed_zero.is_f32_exact());
        assert_eq!(signed_zero.to_sparse_f32().values[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn tree_swept_frames_reject_truncation() {
        let msg = NodeMessage::TreeSwept(TreeSwept {
            db: TreePayload { dim: 8, indices: vec![1], values: vec![2.0] },
            dm: TreePayload { dim: 8, indices: vec![0, 2], values: vec![0.1 + 0.2, 1.0] },
            origins: vec![OriginStat { machine: 0, compute_secs: 0.0, db_nnz: 1, dm_nnz: 2 }],
            edges: vec![],
        });
        let bytes = msg.encode();
        for cut in 0..bytes.len() {
            assert!(NodeMessage::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(NodeMessage::decode(&padded).is_err());
        // a malformed raw-f64 section (unsorted indices) is rejected
        let raw = TreePayload { dim: 8, indices: vec![5, 2], values: vec![0.1 + 0.2, 0.3 + 0.4] };
        let mut out = Vec::new();
        put_tree_payload(&mut out, &raw, MessageClass::Margins);
        let mut pos = 0;
        assert!(get_tree_payload(&out, &mut pos).is_err());
    }

    #[test]
    fn checksums_are_order_and_value_sensitive() {
        assert_ne!(crc_f32(&[1.0, 2.0]), crc_f32(&[2.0, 1.0]));
        assert_ne!(crc_f32(&[1.0]), crc_f32(&[1.0 + 1e-7]));
        assert_eq!(crc_f32(&[]), crc_f32(&[]));
        // -0.0 and 0.0 differ in bits, so they differ in crc (bit-exactness)
        assert_ne!(crc_f32(&[0.0]), crc_f32(&[-0.0]));
        assert_ne!(crc_u32(&[1, 2, 3]), crc_u32(&[1, 3, 2]));
    }
}
