//! Transport abstraction for the node protocol: the leader drives every
//! worker through a [`Transport`] — an ordered, reliable, bidirectional
//! [`NodeMessage`] stream — so the `FitDriver` send/recv phases are
//! byte-stream-agnostic.
//!
//! Two implementations exist:
//!
//! * the **in-process channel links** the `WorkerPool` builds around its
//!   worker threads (private to `solver::pool` — they multiplex a
//!   [`TaskExecutor`](crate::cluster::comm::TaskExecutor) lane next to the
//!   protocol lane): `NodeMessage` values move over mpsc channels without
//!   serialization, so owned buffers transfer and the hot path stays
//!   allocation-free;
//! * [`SocketTransport`] (here) — a real multi-process byte stream over
//!   TCP: length-prefixed frames (`[u32 len][body]`) whose bodies are the
//!   [`NodeMessage`] codec encoding, so sparse Δ-payloads cross the wire
//!   in exactly the bytes the `comm_bytes` ledger's cost model charges
//!   under the default lossless policy.
//!
//! Fault model: a peer that disappears (process death, dropped channel,
//! closed socket) surfaces as a clean [`DlrError`] from `send`/`recv` —
//! never a hang on a half-written frame, never a panic. Malformed frames
//! (garbage tags, lying length prefixes, truncated payloads) error through
//! the protocol decoder like the codec truncation tests.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{IpAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::protocol::{NodeMessage, PeerInfo, Topology, MAX_FRAME_BODY};
use crate::error::{DlrError, Result};

/// Shared bytes-on-wire totals one process accumulates across all of its
/// links (leader link + every peer link). The physical-topology bench reads
/// these to compare measured leader vs worker bandwidth; counts are *frame*
/// bytes (body + 4-byte length prefix), i.e. exactly what crossed the TCP
/// stream. The in-process links count the frame their message would encode
/// to, so star-topology reports are comparable across transports.
#[derive(Debug, Default)]
pub struct WireCounters {
    pub sent: AtomicU64,
    pub recv: AtomicU64,
}

impl WireCounters {
    pub fn totals(&self) -> (u64, u64) {
        (self.sent.load(Ordering::Relaxed), self.recv.load(Ordering::Relaxed))
    }
}

/// An ordered, reliable, bidirectional message stream to one peer node.
pub trait Transport: Send {
    /// Deliver one message. Errors if the peer is gone.
    fn send(&mut self, msg: NodeMessage) -> Result<()>;

    /// Block for the peer's next message. Errors (promptly, without
    /// hanging) if the peer is gone or sends a malformed frame.
    fn recv(&mut self) -> Result<NodeMessage>;

    /// Wait up to `wait` for the peer's next message without disturbing the
    /// stream: `Ok(None)` when no frame *started* within the window,
    /// `Ok(Some(..))` once a frame arrives (the remainder of a started
    /// frame is read under the configured recv deadline, so a short poll
    /// window never desyncs mid-frame). Tree workers alternate polls over
    /// their leader and parent links with this.
    fn recv_poll(&mut self, wait: Duration) -> Result<Option<NodeMessage>> {
        let _ = wait;
        Err(DlrError::Solver(format!(
            "recv_poll is not supported by the {} transport",
            self.kind()
        )))
    }

    /// Total frame bytes this link has sent / received since creation.
    /// Transports that do not meter themselves report zero.
    fn bytes_sent(&self) -> u64 {
        0
    }
    fn bytes_recv(&self) -> u64 {
        0
    }

    /// Bound every subsequent [`recv`](Transport::recv): a peer that stays
    /// silent past the deadline errors with a "timed out" message instead
    /// of wedging the leader forever. `None` (the default) blocks
    /// indefinitely. Transports that detect peer death immediately (the
    /// in-process channel links — a dead worker thread disconnects its
    /// channel) ignore the call. After a deadline fires mid-frame the
    /// stream position is unspecified; the only safe continuation is to
    /// replace or drop the link.
    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        let _ = deadline;
        Ok(())
    }

    /// `"in-process"` or `"socket"` — for logs and bench records.
    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// TCP byte stream
// ---------------------------------------------------------------------------

/// Multi-process transport endpoint: length-prefixed [`NodeMessage`]
/// frames over a TCP stream (`TCP_NODELAY`, buffered both ways, flushed
/// per message — the protocol is strictly request/reply).
pub struct SocketTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    deadline: Option<Duration>,
    sent: u64,
    recv: u64,
    shared: Option<Arc<WireCounters>>,
}

impl SocketTransport {
    /// Wrap an accepted / connected stream.
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(Self { reader, writer, deadline: None, sent: 0, recv: 0, shared: None })
    }

    /// Also accumulate this link's frame bytes into a process-wide
    /// [`WireCounters`] (per-node totals across leader + peer links).
    pub fn share_counters(&mut self, counters: Arc<WireCounters>) {
        self.shared = Some(counters);
    }

    /// The local IP this socket is bound on — a tree worker advertises its
    /// peer listener on the same interface it reached the leader through.
    pub fn local_ip(&self) -> Result<IpAddr> {
        Ok(self.reader.get_ref().local_addr()?.ip())
    }

    /// Connect to a listening leader.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with retries until `timeout` — workers routinely start
    /// before the leader finishes binding, so a one-shot connect would make
    /// every launch script racy. Retries back off exponentially (10 ms
    /// doubling to a 640 ms cap, deterministic — no RNG) so a fleet of
    /// waiting workers doesn't hammer a leader that is seconds away from
    /// binding.
    pub fn connect_retry(addr: impl ToSocketAddrs + Clone, timeout: Duration) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        let mut attempts = 0u32;
        loop {
            match TcpStream::connect(addr.clone()) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) => {
                    attempts += 1;
                    if Instant::now() >= deadline {
                        return Err(DlrError::Solver(format!(
                            "could not reach the leader within {:.1}s \
                             (after {attempts} attempts): {e}",
                            timeout.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(backoff_delay(attempts));
                }
            }
        }
    }
}

/// The `connect_retry` backoff schedule: 10 ms after the first failed
/// attempt, doubling per attempt, capped at 640 ms.
fn backoff_delay(attempt: u32) -> Duration {
    Duration::from_millis(10u64 << attempt.saturating_sub(1).min(6))
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        let body = msg.encode();
        self.writer.write_all(&(body.len() as u32).to_le_bytes())?;
        self.writer.write_all(&body)?;
        self.writer.flush()?;
        let frame = body.len() as u64 + 4;
        self.sent += frame;
        if let Some(c) = &self.shared {
            c.sent.fetch_add(frame, Ordering::Relaxed);
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        let mut len_buf = [0u8; 4];
        self.reader.read_exact(&mut len_buf).map_err(hangup)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME_BODY {
            return Err(DlrError::parse(
                "wire",
                format!("frame length {len} exceeds the {MAX_FRAME_BODY}-byte cap"),
            ));
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).map_err(hangup)?;
        let frame = len as u64 + 4;
        self.recv += frame;
        if let Some(c) = &self.shared {
            c.recv.fetch_add(frame, Ordering::Relaxed);
        }
        NodeMessage::decode(&body)
    }

    fn recv_poll(&mut self, wait: Duration) -> Result<Option<NodeMessage>> {
        // a zero read-timeout is rejected by the OS; clamp the poll window
        self.reader.get_ref().set_read_timeout(Some(wait.max(Duration::from_millis(1))))?;
        let started = match self.reader.fill_buf() {
            Ok(buf) if buf.is_empty() => Err(hangup(std::io::Error::from(
                std::io::ErrorKind::UnexpectedEof,
            ))),
            Ok(_) => Ok(true),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(false)
            }
            Err(e) => Err(hangup(e)),
        };
        // restore the configured deadline before finishing (or skipping)
        // the frame, so a started frame reads under the normal recv rules
        self.reader.get_ref().set_read_timeout(self.deadline)?;
        if started? {
            self.recv().map(Some)
        } else {
            Ok(None)
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_recv(&self) -> u64 {
        self.recv
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(deadline)?;
        self.deadline = deadline;
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "socket"
    }
}

/// EOF mid-frame means the peer died; a read timeout means the peer is
/// wedged past the recv deadline — report both as such rather than a bare
/// io error.
fn hangup(e: std::io::Error) -> DlrError {
    match e.kind() {
        std::io::ErrorKind::UnexpectedEof => {
            DlrError::Solver("peer node hung up mid-frame".into())
        }
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => DlrError::Solver(
            "peer node timed out (no frame within the recv deadline)".into(),
        ),
        _ => DlrError::Io(e),
    }
}

// ---------------------------------------------------------------------------
// Worker↔worker peer links (tree topology)
// ---------------------------------------------------------------------------

/// A tree worker's side of the physical collective topology: the listener
/// its peers dial, the link to its bracket parent, and one link per bracket
/// child — rebuilt from every [`Topology`] the leader issues.
///
/// The rebuild handshake mirrors the leader-join path: the dialing child
/// sends a [`NodeMessage::PeerHello`] carrying its machine index, the
/// topology epoch, and its owned-column checksum; the accepting parent
/// validates all three against the [`PeerInfo`] in its own topology before
/// acking the link. Hellos from a stale epoch (links left over from a
/// previous tree) are dropped without an ack, so a replaced worker's old
/// peers can never leak into the rebuilt tree.
///
/// The cascade is deadlock-free by induction on bracket depth: a worker
/// dials its parent *before* accepting its own children, the TCP accept
/// backlog holds those children's connects in the meantime, and machine 0
/// (no worker parent) accepts immediately.
pub struct PeerTable {
    listener: TcpListener,
    advertised: String,
    counters: Option<Arc<WireCounters>>,
    epoch: u32,
    parent: Option<SocketTransport>,
    children: Vec<(u32, SocketTransport)>,
}

impl PeerTable {
    /// Bind the peer listener on an ephemeral port of `ip` (the interface
    /// the worker reached the leader through — see
    /// [`SocketTransport::local_ip`]). The advertised address travels to
    /// the leader in `Join.listen_addr`.
    pub fn bind(ip: IpAddr) -> Result<Self> {
        let listener = TcpListener::bind((ip, 0))?;
        let advertised = listener.local_addr()?.to_string();
        Ok(Self {
            listener,
            advertised,
            counters: None,
            epoch: 0,
            parent: None,
            children: Vec::new(),
        })
    }

    /// Accumulate all peer-link frame bytes into `counters` (shared with
    /// the worker's leader link for per-node totals).
    pub fn share_counters(&mut self, counters: Arc<WireCounters>) {
        self.counters = Some(counters);
    }

    /// The `ip:port` peers dial, as advertised in `Join.listen_addr`.
    pub fn advertised_addr(&self) -> &str {
        &self.advertised
    }

    /// Epoch of the topology the current links were built from.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// The link toward the bracket parent (`None` on machine 0, whose
    /// parent is the leader).
    pub fn parent_mut(&mut self) -> Option<&mut SocketTransport> {
        self.parent.as_mut()
    }

    /// Child links in bracket merge order, keyed by machine index.
    pub fn children_mut(&mut self) -> &mut [(u32, SocketTransport)] {
        &mut self.children
    }

    /// Drop every peer link (a repair is starting; the next [`Topology`]
    /// rebuilds them).
    pub fn drop_links(&mut self) {
        self.parent = None;
        self.children.clear();
    }

    /// Tear down and re-establish every peer link from a fresh topology
    /// view: dial the parent (hello → ack), then accept each expected
    /// child (hello → validate → ack). Identity-validation failures drop
    /// the offending connection and keep waiting; only the deadline errors.
    pub fn rebuild(&mut self, topo: &Topology, machine: u32, cols_checksum: u64) -> Result<()> {
        self.drop_links();
        self.epoch = topo.epoch;
        let timeout = if topo.peer_timeout_secs > 0.0 {
            Duration::from_secs_f64(topo.peer_timeout_secs)
        } else {
            Duration::from_secs(30)
        };
        let link_deadline = (topo.peer_timeout_secs > 0.0)
            .then(|| Duration::from_secs_f64(topo.peer_timeout_secs));
        if let Some(parent) = &topo.parent {
            let mut link = SocketTransport::connect_retry(parent.addr.as_str(), timeout)
                .map_err(|e| {
                    DlrError::Solver(format!(
                        "could not dial tree parent {} at {}: {e}",
                        parent.machine, parent.addr
                    ))
                })?;
            if let Some(c) = &self.counters {
                link.share_counters(Arc::clone(c));
            }
            link.set_recv_deadline(Some(timeout))?;
            link.send(NodeMessage::PeerHello { machine, epoch: topo.epoch, cols_checksum })?;
            match link.recv() {
                Ok(NodeMessage::Ack) => {}
                Ok(NodeMessage::Abort { message }) => {
                    return Err(DlrError::Solver(format!(
                        "tree parent {} rejected the peer link: {message}",
                        parent.machine
                    )))
                }
                Ok(other) => {
                    return Err(DlrError::Solver(format!(
                        "tree parent {} answered the peer hello with {}",
                        parent.machine,
                        other.name()
                    )))
                }
                Err(e) => {
                    return Err(DlrError::Solver(format!(
                        "no ack from tree parent {}: {e}",
                        parent.machine
                    )))
                }
            }
            link.set_recv_deadline(link_deadline)?;
            self.parent = Some(link);
        }
        if topo.children.is_empty() {
            return Ok(());
        }
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<SocketTransport>> =
            topo.children.iter().map(|_| None).collect();
        self.listener.set_nonblocking(true)?;
        let outcome = loop {
            if slots.iter().all(|s| s.is_some()) {
                break Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if let Some((slot, link)) =
                        self.admit_child(stream, topo, link_deadline, timeout)
                    {
                        // a retrying dialer replaces its own earlier link
                        slots[slot] = Some(link);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        let missing: Vec<u32> = topo
                            .children
                            .iter()
                            .zip(&slots)
                            .filter(|(_, s)| s.is_none())
                            .map(|(c, _)| c.machine)
                            .collect();
                        break Err(DlrError::Solver(format!(
                            "timed out waiting for tree children {missing:?} \
                             (epoch {})",
                            topo.epoch
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => break Err(DlrError::Io(e)),
            }
        };
        self.listener.set_nonblocking(false)?;
        outcome?;
        self.children = topo
            .children
            .iter()
            .zip(slots)
            .map(|(c, s)| (c.machine, s.expect("all child slots filled")))
            .collect();
        Ok(())
    }

    /// Handshake one accepted connection; `None` drops it (stale epoch,
    /// unknown machine, dead dialer) and `rebuild` keeps waiting.
    fn admit_child(
        &self,
        stream: TcpStream,
        topo: &Topology,
        link_deadline: Option<Duration>,
        timeout: Duration,
    ) -> Option<(usize, SocketTransport)> {
        stream.set_nonblocking(false).ok()?;
        let mut link = SocketTransport::from_stream(stream).ok()?;
        if let Some(c) = &self.counters {
            link.share_counters(Arc::clone(c));
        }
        link.set_recv_deadline(Some(timeout)).ok()?;
        let NodeMessage::PeerHello { machine, epoch, cols_checksum } = link.recv().ok()?
        else {
            return None;
        };
        if epoch != topo.epoch {
            return None; // stale dialer from a previous tree
        }
        let slot = topo.children.iter().position(|c| c.machine == machine)?;
        if topo.children[slot].cols_checksum != cols_checksum {
            let _ = link.send(NodeMessage::Abort {
                message: format!("peer hello shard checksum mismatch for machine {machine}"),
            });
            return None;
        }
        link.send(NodeMessage::Ack).ok()?;
        link.set_recv_deadline(link_deadline).ok()?;
        Some((slot, link))
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// What a [`FaultyTransport`] does to its trigger frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail the recv as if the peer died, leaving the real frame unread.
    Drop,
    /// Sleep for the given duration, then deliver the frame intact.
    Delay(Duration),
    /// Consume the peer's real frame but hand the caller its encoding cut
    /// one byte short — the shape of a half-delivered frame.
    Truncate,
    /// Consume the peer's real frame but hand the caller a garbage frame
    /// with an unknown tag — the shape of bytes flipped in flight.
    Corrupt,
}

/// Fault-injection wrapper for tests and chaos harnesses: passes every
/// call through to the wrapped transport except the `at`-th received
/// message (1-based, counted across blocking `recv`s and delivering
/// `recv_poll`s alike — a tree worker's polled serve loop is injurable
/// the same as a star worker's blocking one), which it injures with the
/// configured [`Fault`].
/// `Truncate`/`Corrupt` consume the peer's real reply before substituting
/// damaged bytes, so the peer itself stays healthy and in protocol — a
/// corrupted link, not a dead process.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    fault: Fault,
    at: usize,
    seen: usize,
}

impl FaultyTransport {
    pub fn new(inner: Box<dyn Transport>, fault: Fault, at: usize) -> Self {
        Self { inner, fault, at, seen: 0 }
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        self.seen += 1;
        if self.seen != self.at {
            return self.inner.recv();
        }
        match self.fault {
            Fault::Drop => Err(DlrError::Solver("peer node hung up mid-frame".into())),
            Fault::Delay(d) => {
                std::thread::sleep(d);
                self.inner.recv()
            }
            Fault::Truncate => {
                let body = self.inner.recv()?.encode();
                NodeMessage::decode(&body[..body.len() - 1])
            }
            Fault::Corrupt => {
                self.inner.recv()?;
                NodeMessage::decode(&[77, 1, 2])
            }
        }
    }

    fn recv_poll(&mut self, wait: Duration) -> Result<Option<NodeMessage>> {
        // empty polls don't count — only delivered messages advance the
        // trigger, keeping `at` meaningful under a polling serve loop
        match self.inner.recv_poll(wait)? {
            None => Ok(None),
            Some(msg) => {
                self.seen += 1;
                if self.seen != self.at {
                    return Ok(Some(msg));
                }
                match self.fault {
                    Fault::Drop => {
                        Err(DlrError::Solver("peer node hung up mid-frame".into()))
                    }
                    Fault::Delay(d) => {
                        std::thread::sleep(d);
                        Ok(Some(msg))
                    }
                    Fault::Truncate => {
                        let body = msg.encode();
                        NodeMessage::decode(&body[..body.len() - 1]).map(Some)
                    }
                    Fault::Corrupt => NodeMessage::decode(&[77, 1, 2]).map(Some),
                }
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn bytes_recv(&self) -> u64 {
        self.inner.bytes_recv()
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        self.inner.set_recv_deadline(deadline)
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::Arc;

    use crate::data::sparse::SparseVec;

    #[test]
    fn socket_round_trips_messages_bit_exactly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            // echo one message back
            let msg = t.recv().unwrap();
            t.send(msg).unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert_eq!(t.kind(), "socket");
        let dm = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.5e-8, 0.0]);
        t.send(NodeMessage::Apply {
            alpha: 0.625,
            dmargins: Arc::new(dm.clone()),
            delta: None,
        })
        .unwrap();
        match t.recv().unwrap() {
            NodeMessage::Apply { alpha, dmargins, delta } => {
                assert_eq!(alpha.to_bits(), 0.625f32.to_bits());
                assert_eq!(*dmargins, dm);
                assert!(delta.is_none());
            }
            other => panic!("unexpected echo {}", other.name()),
        }
        peer.join().unwrap();
    }

    #[test]
    fn socket_peer_death_is_a_clean_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            // accept, then die without a word
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        peer.join().unwrap();
        let err = t.recv().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err}");
    }

    #[test]
    fn socket_rejects_lying_length_prefix_and_garbage() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // a frame claiming 2 GiB, then a valid-length garbage frame
            stream.write_all(&(u32::MAX).to_le_bytes()).unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("cap"));
        // stream position is corrupt after a rejected frame; a fresh
        // connection reading the garbage frame errors on the unknown tag
        peer.join().unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("unknown message tag"));
        peer.join().unwrap();
    }

    #[test]
    fn connect_retry_times_out_with_context() {
        // a bound-then-dropped listener leaves the port closed
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = SocketTransport::connect_retry(addr, Duration::from_millis(120))
            .unwrap_err()
            .to_string();
        assert!(err.contains("could not reach the leader"), "{err}");
        assert!(err.contains("attempts"), "{err}");
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_capped() {
        let ms: Vec<u64> =
            (1..=9).map(|a| backoff_delay(a).as_millis() as u64).collect();
        assert_eq!(ms, vec![10, 20, 40, 80, 160, 320, 640, 640, 640]);
    }

    #[test]
    fn recv_deadline_turns_a_wedged_peer_into_a_clean_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let peer = std::thread::spawn(move || {
            // hold the connection open but never write a byte
            let (stream, _) = listener.accept().unwrap();
            let _ = done_rx.recv();
            drop(stream);
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        t.set_recv_deadline(Some(Duration::from_millis(60))).unwrap();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        done_tx.send(()).unwrap();
        peer.join().unwrap();
    }

    #[test]
    fn byte_counters_meter_exact_frame_bytes_on_both_sides() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(msg).unwrap();
            (t.bytes_sent(), t.bytes_recv())
        });
        let shared = Arc::new(WireCounters::default());
        let mut t = SocketTransport::connect(addr).unwrap();
        t.share_counters(Arc::clone(&shared));
        let msg = NodeMessage::Abort { message: "counted".into() };
        let frame = msg.encode().len() as u64 + 4;
        t.send(msg).unwrap();
        t.recv().unwrap();
        assert_eq!(t.bytes_sent(), frame);
        assert_eq!(t.bytes_recv(), frame);
        assert_eq!(shared.totals(), (frame, frame));
        let (peer_sent, peer_recv) = peer.join().unwrap();
        assert_eq!(peer_sent, frame);
        assert_eq!(peer_recv, frame);
    }

    #[test]
    fn recv_poll_times_out_quietly_and_delivers_when_data_arrives() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            go_rx.recv().unwrap();
            t.send(NodeMessage::Ping).unwrap();
            go_rx.recv().unwrap(); // hold the stream open until released
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        t.set_recv_deadline(Some(Duration::from_secs(5))).unwrap();
        // nothing on the wire yet: poll returns None, stream stays in sync
        assert!(t.recv_poll(Duration::from_millis(20)).unwrap().is_none());
        go_tx.send(()).unwrap();
        let mut got = None;
        for _ in 0..200 {
            got = t.recv_poll(Duration::from_millis(25)).unwrap();
            if got.is_some() {
                break;
            }
        }
        assert!(matches!(got, Some(NodeMessage::Ping)));
        go_tx.send(()).unwrap();
        peer.join().unwrap();
        // peer gone: poll reports the hangup instead of spinning forever
        let err = loop {
            match t.recv_poll(Duration::from_millis(25)) {
                Ok(None) => continue,
                Ok(Some(m)) => panic!("unexpected {}", m.name()),
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("hung up"), "{err}");
    }

    #[test]
    fn peer_table_builds_a_chain_and_rejects_bad_identity() {
        use crate::cluster::protocol::{PeerInfo, Topology};
        let ip: IpAddr = "127.0.0.1".parse().unwrap();
        // machine 1 (parent end) accepts machine 3 (child end)
        let mut parent_table = PeerTable::bind(ip).unwrap();
        let mut child_table = PeerTable::bind(ip).unwrap();
        let parent_addr = parent_table.advertised_addr().to_string();
        let child_info =
            PeerInfo { machine: 3, addr: child_table.advertised_addr().into(), cols_checksum: 9 };
        let parent_topo = Topology {
            epoch: 4,
            parent: None,
            children: vec![child_info],
            peer_timeout_secs: 5.0,
        };
        let child_topo = Topology {
            epoch: 4,
            parent: Some(PeerInfo { machine: 1, addr: parent_addr.clone(), cols_checksum: 7 }),
            children: vec![],
            peer_timeout_secs: 5.0,
        };
        let child = std::thread::spawn(move || {
            child_table.rebuild(&child_topo, 3, 9).unwrap();
            // send one message up the fresh parent link
            child_table.parent_mut().unwrap().send(NodeMessage::Pong).unwrap();
            child_table
        });
        parent_table.rebuild(&parent_topo, 1, 7).unwrap();
        assert_eq!(parent_table.epoch(), 4);
        let children = parent_table.children_mut();
        assert_eq!(children.len(), 1);
        assert_eq!(children[0].0, 3);
        assert!(matches!(children[0].1.recv().unwrap(), NodeMessage::Pong));
        child.join().unwrap();

        // a dialer presenting the wrong shard checksum is rejected with an
        // abort, and the parent times out still waiting for the real child
        let mut parent_table = PeerTable::bind(ip).unwrap();
        let parent_addr = parent_table.advertised_addr().to_string();
        let bad_topo = Topology {
            epoch: 5,
            parent: Some(PeerInfo { machine: 1, addr: parent_addr, cols_checksum: 7 }),
            children: vec![],
            peer_timeout_secs: 0.4,
        };
        let expect = Topology {
            epoch: 5,
            parent: None,
            children: vec![PeerInfo { machine: 3, addr: "unused".into(), cols_checksum: 9 }],
            peer_timeout_secs: 0.4,
        };
        let mut liar = PeerTable::bind(ip).unwrap();
        let child = std::thread::spawn(move || {
            let err = liar.rebuild(&bad_topo, 3, 1234).unwrap_err().to_string();
            assert!(err.contains("checksum mismatch"), "{err}");
        });
        let err = parent_table.rebuild(&expect, 1, 7).unwrap_err().to_string();
        assert!(err.contains("timed out waiting for tree children"), "{err}");
        child.join().unwrap();
    }

    #[test]
    fn faulty_transport_injures_exactly_the_nth_recv() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            for _ in 0..3 {
                let msg = t.recv().unwrap();
                t.send(msg).unwrap();
            }
        });
        let inner = Box::new(SocketTransport::connect(addr).unwrap());
        let mut t = FaultyTransport::new(inner, Fault::Corrupt, 2);
        for round in 1..=3u32 {
            t.send(NodeMessage::Ping).unwrap();
            match t.recv() {
                Ok(msg) => {
                    assert_ne!(round, 2, "round 2 must be injured");
                    assert!(matches!(msg, NodeMessage::Ping));
                }
                Err(e) => {
                    assert_eq!(round, 2, "only round 2 is injured: {e}");
                    assert!(e.to_string().contains("unknown message tag"), "{e}");
                }
            }
        }
        peer.join().unwrap();
    }
}
