//! Transport abstraction for the node protocol: the leader drives every
//! worker through a [`Transport`] — an ordered, reliable, bidirectional
//! [`NodeMessage`] stream — so the `FitDriver` send/recv phases are
//! byte-stream-agnostic.
//!
//! Two implementations exist:
//!
//! * the **in-process channel links** the `WorkerPool` builds around its
//!   worker threads (private to `solver::pool` — they multiplex a
//!   [`TaskExecutor`](crate::cluster::comm::TaskExecutor) lane next to the
//!   protocol lane): `NodeMessage` values move over mpsc channels without
//!   serialization, so owned buffers transfer and the hot path stays
//!   allocation-free;
//! * [`SocketTransport`] (here) — a real multi-process byte stream over
//!   TCP: length-prefixed frames (`[u32 len][body]`) whose bodies are the
//!   [`NodeMessage`] codec encoding, so sparse Δ-payloads cross the wire
//!   in exactly the bytes the `comm_bytes` ledger's cost model charges
//!   under the default lossless policy.
//!
//! Fault model: a peer that disappears (process death, dropped channel,
//! closed socket) surfaces as a clean [`DlrError`] from `send`/`recv` —
//! never a hang on a half-written frame, never a panic. Malformed frames
//! (garbage tags, lying length prefixes, truncated payloads) error through
//! the protocol decoder like the codec truncation tests.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::cluster::protocol::{NodeMessage, MAX_FRAME_BODY};
use crate::error::{DlrError, Result};

/// An ordered, reliable, bidirectional message stream to one peer node.
pub trait Transport: Send {
    /// Deliver one message. Errors if the peer is gone.
    fn send(&mut self, msg: NodeMessage) -> Result<()>;

    /// Block for the peer's next message. Errors (promptly, without
    /// hanging) if the peer is gone or sends a malformed frame.
    fn recv(&mut self) -> Result<NodeMessage>;

    /// `"in-process"` or `"socket"` — for logs and bench records.
    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// TCP byte stream
// ---------------------------------------------------------------------------

/// Multi-process transport endpoint: length-prefixed [`NodeMessage`]
/// frames over a TCP stream (`TCP_NODELAY`, buffered both ways, flushed
/// per message — the protocol is strictly request/reply).
pub struct SocketTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl SocketTransport {
    /// Wrap an accepted / connected stream.
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(Self { reader, writer })
    }

    /// Connect to a listening leader.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with retries until `timeout` — workers routinely start
    /// before the leader finishes binding, so a one-shot connect would make
    /// every launch script racy.
    pub fn connect_retry(addr: impl ToSocketAddrs + Clone, timeout: Duration) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr.clone()) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(DlrError::Solver(format!(
                            "could not reach the leader within {:.1}s: {e}",
                            timeout.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        let body = msg.encode();
        self.writer.write_all(&(body.len() as u32).to_le_bytes())?;
        self.writer.write_all(&body)?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        let mut len_buf = [0u8; 4];
        self.reader.read_exact(&mut len_buf).map_err(hangup)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME_BODY {
            return Err(DlrError::parse(
                "wire",
                format!("frame length {len} exceeds the {MAX_FRAME_BODY}-byte cap"),
            ));
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).map_err(hangup)?;
        NodeMessage::decode(&body)
    }

    fn kind(&self) -> &'static str {
        "socket"
    }
}

/// EOF mid-frame means the peer died — report it as such rather than a
/// bare io error.
fn hangup(e: std::io::Error) -> DlrError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        DlrError::Solver("peer node hung up mid-frame".into())
    } else {
        DlrError::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::Arc;

    use crate::data::sparse::SparseVec;

    #[test]
    fn socket_round_trips_messages_bit_exactly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            // echo one message back
            let msg = t.recv().unwrap();
            t.send(msg).unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert_eq!(t.kind(), "socket");
        let dm = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.5e-8, 0.0]);
        t.send(NodeMessage::Apply {
            alpha: 0.625,
            dmargins: Arc::new(dm.clone()),
            delta: None,
        })
        .unwrap();
        match t.recv().unwrap() {
            NodeMessage::Apply { alpha, dmargins, delta } => {
                assert_eq!(alpha.to_bits(), 0.625f32.to_bits());
                assert_eq!(*dmargins, dm);
                assert!(delta.is_none());
            }
            other => panic!("unexpected echo {}", other.name()),
        }
        peer.join().unwrap();
    }

    #[test]
    fn socket_peer_death_is_a_clean_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            // accept, then die without a word
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        peer.join().unwrap();
        let err = t.recv().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err}");
    }

    #[test]
    fn socket_rejects_lying_length_prefix_and_garbage() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // a frame claiming 2 GiB, then a valid-length garbage frame
            stream.write_all(&(u32::MAX).to_le_bytes()).unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("cap"));
        // stream position is corrupt after a rejected frame; a fresh
        // connection reading the garbage frame errors on the unknown tag
        peer.join().unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("unknown message tag"));
        peer.join().unwrap();
    }

    #[test]
    fn connect_retry_times_out_with_context() {
        // a bound-then-dropped listener leaves the port closed
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err =
            SocketTransport::connect_retry(addr, Duration::from_millis(120)).unwrap_err();
        assert!(err.to_string().contains("could not reach the leader"), "{err}");
    }
}
