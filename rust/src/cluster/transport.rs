//! Transport abstraction for the node protocol: the leader drives every
//! worker through a [`Transport`] — an ordered, reliable, bidirectional
//! [`NodeMessage`] stream — so the `FitDriver` send/recv phases are
//! byte-stream-agnostic.
//!
//! Two implementations exist:
//!
//! * the **in-process channel links** the `WorkerPool` builds around its
//!   worker threads (private to `solver::pool` — they multiplex a
//!   [`TaskExecutor`](crate::cluster::comm::TaskExecutor) lane next to the
//!   protocol lane): `NodeMessage` values move over mpsc channels without
//!   serialization, so owned buffers transfer and the hot path stays
//!   allocation-free;
//! * [`SocketTransport`] (here) — a real multi-process byte stream over
//!   TCP: length-prefixed frames (`[u32 len][body]`) whose bodies are the
//!   [`NodeMessage`] codec encoding, so sparse Δ-payloads cross the wire
//!   in exactly the bytes the `comm_bytes` ledger's cost model charges
//!   under the default lossless policy.
//!
//! Fault model: a peer that disappears (process death, dropped channel,
//! closed socket) surfaces as a clean [`DlrError`] from `send`/`recv` —
//! never a hang on a half-written frame, never a panic. Malformed frames
//! (garbage tags, lying length prefixes, truncated payloads) error through
//! the protocol decoder like the codec truncation tests.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::cluster::protocol::{NodeMessage, MAX_FRAME_BODY};
use crate::error::{DlrError, Result};

/// An ordered, reliable, bidirectional message stream to one peer node.
pub trait Transport: Send {
    /// Deliver one message. Errors if the peer is gone.
    fn send(&mut self, msg: NodeMessage) -> Result<()>;

    /// Block for the peer's next message. Errors (promptly, without
    /// hanging) if the peer is gone or sends a malformed frame.
    fn recv(&mut self) -> Result<NodeMessage>;

    /// Bound every subsequent [`recv`](Transport::recv): a peer that stays
    /// silent past the deadline errors with a "timed out" message instead
    /// of wedging the leader forever. `None` (the default) blocks
    /// indefinitely. Transports that detect peer death immediately (the
    /// in-process channel links — a dead worker thread disconnects its
    /// channel) ignore the call. After a deadline fires mid-frame the
    /// stream position is unspecified; the only safe continuation is to
    /// replace or drop the link.
    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        let _ = deadline;
        Ok(())
    }

    /// `"in-process"` or `"socket"` — for logs and bench records.
    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// TCP byte stream
// ---------------------------------------------------------------------------

/// Multi-process transport endpoint: length-prefixed [`NodeMessage`]
/// frames over a TCP stream (`TCP_NODELAY`, buffered both ways, flushed
/// per message — the protocol is strictly request/reply).
pub struct SocketTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl SocketTransport {
    /// Wrap an accepted / connected stream.
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(Self { reader, writer })
    }

    /// Connect to a listening leader.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Connect with retries until `timeout` — workers routinely start
    /// before the leader finishes binding, so a one-shot connect would make
    /// every launch script racy. Retries back off exponentially (10 ms
    /// doubling to a 640 ms cap, deterministic — no RNG) so a fleet of
    /// waiting workers doesn't hammer a leader that is seconds away from
    /// binding.
    pub fn connect_retry(addr: impl ToSocketAddrs + Clone, timeout: Duration) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        let mut attempts = 0u32;
        loop {
            match TcpStream::connect(addr.clone()) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) => {
                    attempts += 1;
                    if Instant::now() >= deadline {
                        return Err(DlrError::Solver(format!(
                            "could not reach the leader within {:.1}s \
                             (after {attempts} attempts): {e}",
                            timeout.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(backoff_delay(attempts));
                }
            }
        }
    }
}

/// The `connect_retry` backoff schedule: 10 ms after the first failed
/// attempt, doubling per attempt, capped at 640 ms.
fn backoff_delay(attempt: u32) -> Duration {
    Duration::from_millis(10u64 << attempt.saturating_sub(1).min(6))
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        let body = msg.encode();
        self.writer.write_all(&(body.len() as u32).to_le_bytes())?;
        self.writer.write_all(&body)?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        let mut len_buf = [0u8; 4];
        self.reader.read_exact(&mut len_buf).map_err(hangup)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME_BODY {
            return Err(DlrError::parse(
                "wire",
                format!("frame length {len} exceeds the {MAX_FRAME_BODY}-byte cap"),
            ));
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).map_err(hangup)?;
        NodeMessage::decode(&body)
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(deadline)?;
        Ok(())
    }

    fn kind(&self) -> &'static str {
        "socket"
    }
}

/// EOF mid-frame means the peer died; a read timeout means the peer is
/// wedged past the recv deadline — report both as such rather than a bare
/// io error.
fn hangup(e: std::io::Error) -> DlrError {
    match e.kind() {
        std::io::ErrorKind::UnexpectedEof => {
            DlrError::Solver("peer node hung up mid-frame".into())
        }
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => DlrError::Solver(
            "peer node timed out (no frame within the recv deadline)".into(),
        ),
        _ => DlrError::Io(e),
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// What a [`FaultyTransport`] does to its trigger frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail the recv as if the peer died, leaving the real frame unread.
    Drop,
    /// Sleep for the given duration, then deliver the frame intact.
    Delay(Duration),
    /// Consume the peer's real frame but hand the caller its encoding cut
    /// one byte short — the shape of a half-delivered frame.
    Truncate,
    /// Consume the peer's real frame but hand the caller a garbage frame
    /// with an unknown tag — the shape of bytes flipped in flight.
    Corrupt,
}

/// Fault-injection wrapper for tests and chaos harnesses: passes every
/// call through to the wrapped transport except the `at`-th recv
/// (1-based), which it injures with the configured [`Fault`].
/// `Truncate`/`Corrupt` consume the peer's real reply before substituting
/// damaged bytes, so the peer itself stays healthy and in protocol — a
/// corrupted link, not a dead process.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    fault: Fault,
    at: usize,
    seen: usize,
}

impl FaultyTransport {
    pub fn new(inner: Box<dyn Transport>, fault: Fault, at: usize) -> Self {
        Self { inner, fault, at, seen: 0 }
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, msg: NodeMessage) -> Result<()> {
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<NodeMessage> {
        self.seen += 1;
        if self.seen != self.at {
            return self.inner.recv();
        }
        match self.fault {
            Fault::Drop => Err(DlrError::Solver("peer node hung up mid-frame".into())),
            Fault::Delay(d) => {
                std::thread::sleep(d);
                self.inner.recv()
            }
            Fault::Truncate => {
                let body = self.inner.recv()?.encode();
                NodeMessage::decode(&body[..body.len() - 1])
            }
            Fault::Corrupt => {
                self.inner.recv()?;
                NodeMessage::decode(&[77, 1, 2])
            }
        }
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) -> Result<()> {
        self.inner.set_recv_deadline(deadline)
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::Arc;

    use crate::data::sparse::SparseVec;

    #[test]
    fn socket_round_trips_messages_bit_exactly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            // echo one message back
            let msg = t.recv().unwrap();
            t.send(msg).unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert_eq!(t.kind(), "socket");
        let dm = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.5e-8, 0.0]);
        t.send(NodeMessage::Apply {
            alpha: 0.625,
            dmargins: Arc::new(dm.clone()),
            delta: None,
        })
        .unwrap();
        match t.recv().unwrap() {
            NodeMessage::Apply { alpha, dmargins, delta } => {
                assert_eq!(alpha.to_bits(), 0.625f32.to_bits());
                assert_eq!(*dmargins, dm);
                assert!(delta.is_none());
            }
            other => panic!("unexpected echo {}", other.name()),
        }
        peer.join().unwrap();
    }

    #[test]
    fn socket_peer_death_is_a_clean_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            // accept, then die without a word
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        peer.join().unwrap();
        let err = t.recv().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err}");
    }

    #[test]
    fn socket_rejects_lying_length_prefix_and_garbage() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // a frame claiming 2 GiB, then a valid-length garbage frame
            stream.write_all(&(u32::MAX).to_le_bytes()).unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("cap"));
        // stream position is corrupt after a rejected frame; a fresh
        // connection reading the garbage frame errors on the unknown tag
        peer.join().unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            stream.write_all(&3u32.to_le_bytes()).unwrap();
            stream.write_all(&[77, 1, 2]).unwrap();
            stream.flush().unwrap();
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        assert!(t.recv().unwrap_err().to_string().contains("unknown message tag"));
        peer.join().unwrap();
    }

    #[test]
    fn connect_retry_times_out_with_context() {
        // a bound-then-dropped listener leaves the port closed
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = SocketTransport::connect_retry(addr, Duration::from_millis(120))
            .unwrap_err()
            .to_string();
        assert!(err.contains("could not reach the leader"), "{err}");
        assert!(err.contains("attempts"), "{err}");
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_capped() {
        let ms: Vec<u64> =
            (1..=9).map(|a| backoff_delay(a).as_millis() as u64).collect();
        assert_eq!(ms, vec![10, 20, 40, 80, 160, 320, 640, 640, 640]);
    }

    #[test]
    fn recv_deadline_turns_a_wedged_peer_into_a_clean_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let peer = std::thread::spawn(move || {
            // hold the connection open but never write a byte
            let (stream, _) = listener.accept().unwrap();
            let _ = done_rx.recv();
            drop(stream);
        });
        let mut t = SocketTransport::connect(addr).unwrap();
        t.set_recv_deadline(Some(Duration::from_millis(60))).unwrap();
        let err = t.recv().unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        done_tx.send(()).unwrap();
        peer.join().unwrap();
    }

    #[test]
    fn faulty_transport_injures_exactly_the_nth_recv() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peer = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = SocketTransport::from_stream(stream).unwrap();
            for _ in 0..3 {
                let msg = t.recv().unwrap();
                t.send(msg).unwrap();
            }
        });
        let inner = Box::new(SocketTransport::connect(addr).unwrap());
        let mut t = FaultyTransport::new(inner, Fault::Corrupt, 2);
        for round in 1..=3u32 {
            t.send(NodeMessage::Ping).unwrap();
            match t.recv() {
                Ok(msg) => {
                    assert_ne!(round, 2, "round 2 must be injured");
                    assert!(matches!(msg, NodeMessage::Ping));
                }
                Err(e) => {
                    assert_eq!(round, 2, "only round 2 is injured: {e}");
                    assert!(e.to_string().contains("unknown message tag"), "{e}");
                }
            }
        }
        peer.join().unwrap();
    }
}
