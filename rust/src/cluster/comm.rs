//! The pluggable communication subsystem every Δ-exchange routes through:
//! a [`Collective`] trait over the simulated network (implemented by the
//! tree [`TreeAllReduce`] and the new [`AllGather`]), a [`TaskExecutor`]
//! abstraction that lets tree-node merges run off the calling thread (the
//! solver plugs its `WorkerPool` in, so the leader thread never performs
//! merge work), and the byte-cost estimator the `FitDriver` uses to choose
//! between the reduce-Δm and allgather-Δβ exchange strategies.
//!
//! Wire formats and per-message codec selection live in
//! [`crate::cluster::codec`]; the shared tree engine (deterministic
//! pairwise merge order, per-edge charging) lives in
//! [`crate::cluster::allreduce`].

use crate::cluster::allreduce::{run_sparse_exchange, AllReduceOutcome, AllReduceScratch};
use crate::cluster::codec::{dense_wire_bytes, sparse_wire_bytes, CodecPolicy, MessageClass};
use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::cluster::TreeAllReduce;
use crate::data::sparse::SparseVec;
use crate::error::{DlrError, Result};

/// One unit of off-thread work (a tree-node merge).
pub type Job = Box<dyn FnOnce() + Send>;

/// Runs a batch of independent jobs to completion. `run_all` must not
/// return until every job has executed — the collectives rely on it as a
/// per-round barrier.
pub trait TaskExecutor {
    fn run_all(&self, jobs: Vec<Job>);
}

/// Executes jobs inline on the calling thread (tests, compat wrappers, and
/// callers without a worker pool).
#[derive(Debug, Default)]
pub struct SerialExecutor;

impl TaskExecutor for SerialExecutor {
    fn run_all(&self, jobs: Vec<Job>) {
        for job in jobs {
            job();
        }
    }
}

/// Shared context for one collective call: where to charge bytes, which
/// codecs the policy allows for this message class, who runs the merges,
/// whether the wire is charged at all (`charge = false` models a
/// leader-local recomputation — same deterministic merge, zero bytes), and
/// whether the merged root is broadcast back down the tree
/// (`broadcast = false` models a *gather*: the leader needs the merged
/// vector, the workers do not — the Δβ flow under worker-held β shards,
/// where each node applies `α·Δβ_local` from its own state and the
/// merged-root retrace of the PR-3 accounting no longer exists).
pub struct CommCtx<'a> {
    pub ledger: &'a NetworkLedger,
    pub policy: CodecPolicy,
    pub class: MessageClass,
    pub exec: &'a dyn TaskExecutor,
    pub charge: bool,
    pub broadcast: bool,
}

/// A collective over M per-machine sparse contributions: every machine
/// (and the leader) ends with the merged vector in `out`. Overlapping
/// indices sum in `f64`, in a fixed pairwise tree order, so any two
/// collectives (and any executor) produce bit-identical results.
pub trait Collective {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome;

    fn name(&self) -> &'static str;
}

impl Collective for TreeAllReduce {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        run_sparse_exchange(&self.model, m, contrib, dim, ctx, scratch, out)
    }

    fn name(&self) -> &'static str {
        "tree-allreduce"
    }
}

/// AllGather over the simulated network: gather the M contributions up the
/// binary tree, broadcast the union back down — after which every machine
/// holds the full merged vector. The intended payload is the machines'
/// *disjoint* Δβ shards (a feature partition never overlaps), where gather
/// is pure concatenation; overlapping indices, if any, sum exactly like
/// the reduce, so the result — and the per-edge charge — is bit-identical
/// to [`TreeAllReduce::exchange`](Collective::exchange) (pinned by
/// `allgather_matches_allreduce_bitwise`). The distinct type exists for
/// the semantic contract (every machine ends holding the full vector,
/// which is what lets the Δm reduce be skipped entirely) and as the
/// extension point for true ring/recursive-doubling allgathers.
#[derive(Debug)]
pub struct AllGather {
    pub model: NetworkModel,
}

impl AllGather {
    pub fn new(model: NetworkModel) -> Self {
        Self { model }
    }
}

impl Collective for AllGather {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        run_sparse_exchange(&self.model, m, contrib, dim, ctx, scratch, out)
    }

    fn name(&self) -> &'static str {
        "allgather"
    }
}

/// The deterministic pairwise merge bracket over `m` machines, as a
/// parent/children forest: `children[a]` lists the machines whose
/// accumulated payloads machine `a` merges, **in merge (round) order** —
/// the exact pairing [`run_sparse_exchange`] walks (machine `2k` absorbs
/// `2k+1`, odd survivor promoted). Machine 0 is always the root. A machine
/// finishes all of its own merges before the round in which it is absorbed,
/// so a physical tree that ships each machine's accumulated payload once,
/// then folds children in this order, reproduces the staged engine's f64
/// sums bit for bit. This is the tree the leader hands out as
/// [`crate::cluster::protocol::Topology`].
pub fn bracket_children(m: usize) -> Vec<Vec<u32>> {
    let mut children: Vec<Vec<u32>> = vec![Vec::new(); m];
    if m < 2 {
        return children;
    }
    let mut active: Vec<u32> = (0..m as u32).collect();
    let mut next: Vec<u32> = Vec::new();
    while active.len() > 1 {
        let pairs = active.len() / 2;
        next.clear();
        for t in 0..pairs {
            let a = active[2 * t];
            let b = active[2 * t + 1];
            children[a as usize].push(b);
            next.push(a);
        }
        if active.len() % 2 == 1 {
            next.push(*active.last().unwrap());
        }
        std::mem::swap(&mut active, &mut next);
    }
    children
}

/// Bracket parent of every machine (`None` for the root, machine 0).
pub fn bracket_parent(m: usize) -> Vec<Option<u32>> {
    let mut parent = vec![None; m];
    for (a, kids) in bracket_children(m).iter().enumerate() {
        for &b in kids {
            parent[b as usize] = Some(a as u32);
        }
    }
    parent
}

/// Leader-side ledger replay of one physical-tree exchange: walk the exact
/// bracket [`run_sparse_exchange`] walks and charge every reduce edge (and
/// optionally the per-edge root broadcast) from nnz metadata instead of
/// staged payloads. `edge_nnz(into, from)` reports the nnz of the
/// accumulated payload machine `from` shipped to machine `into` (carried up
/// the tree as [`crate::cluster::protocol::EdgeStat`]s); `root_nnz` is the
/// merged root payload's nnz. Valid only under policies whose per-message
/// cost depends on nnz alone (no f16 for the class — guaranteed by config
/// validation for `topology = tree`): then every charge, and hence the
/// `comm_bytes` ledger, is bit-identical to the staged engine's.
#[allow(clippy::too_many_arguments)]
pub fn replay_tree_charges(
    model: &NetworkModel,
    m: usize,
    dim: usize,
    ledger: &NetworkLedger,
    policy: &CodecPolicy,
    class: MessageClass,
    charge: bool,
    broadcast: bool,
    edge_nnz: &mut dyn FnMut(u32, u32) -> Result<usize>,
    root_nnz: usize,
) -> Result<AllReduceOutcome> {
    let cost_of = |nnz: usize| {
        policy.cost_from_nnz(nnz, dim, class).ok_or_else(|| {
            DlrError::Solver(
                "tree-topology charge replay requires an nnz-only wire cost \
                 (no f16 for this message class)"
                    .into(),
            )
        })
    };
    if m <= 1 {
        return Ok(AllReduceOutcome { rounds: 0, bytes_moved: 0, simulated_secs: 0.0 });
    }
    let mut active: Vec<u32> = (0..m as u32).collect();
    let mut next: Vec<u32> = Vec::new();
    let mut pairs_per_round: Vec<usize> = Vec::new();
    let mut rounds = 0usize;
    let mut bytes = 0u64;
    let mut secs_total = 0f64;
    while active.len() > 1 {
        rounds += 1;
        let mut round_secs = 0f64;
        next.clear();
        let pairs = active.len() / 2;
        pairs_per_round.push(pairs);
        for t in 0..pairs {
            let a = active[2 * t];
            let b = active[2 * t + 1];
            if charge {
                let cost = cost_of(edge_nnz(a, b)?)?;
                let t_secs = ledger.record(model, cost);
                bytes += cost;
                round_secs = round_secs.max(t_secs);
            }
            next.push(a);
        }
        if active.len() % 2 == 1 {
            next.push(*active.last().unwrap());
        }
        std::mem::swap(&mut active, &mut next);
        secs_total += round_secs;
    }
    if charge && broadcast {
        let cost = cost_of(root_nnz)?;
        for &pairs in pairs_per_round.iter().rev() {
            let mut round_secs = 0f64;
            for _ in 0..pairs {
                let t = ledger.record(model, cost);
                bytes += cost;
                round_secs = round_secs.max(t);
            }
            secs_total += round_secs;
        }
    }
    Ok(AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total })
}

/// Per-message cost under the lossless codecs, optionally admitting the
/// delta-varint + f16 codec's *typical* `nnz · 3` size when the policy
/// allows it for the message class (the exact size needs the indices,
/// which a dry estimate does not have).
fn message_cost(nnz: usize, dim: usize, allow_f16: bool) -> u64 {
    let mut cost = sparse_wire_bytes(nnz).min(dense_wire_bytes(dim));
    if allow_f16 {
        cost = cost.min(nnz as u64 * 3);
    }
    cost
}

/// The dry tree walk shared by [`estimate_tree_bytes`] and
/// [`TreeByteEstimator`]: merged-node sizes are upper-bounded by
/// `nnz_a + nnz_b` (overlap is unknown before merging). `nnzs` is a
/// caller-reused scratch buffer and is clobbered by the walk.
fn tree_walk_bytes(nnzs: &mut [usize], dim: usize, broadcast: bool, allow_f16: bool) -> u64 {
    let m = nnzs.len();
    if m <= 1 {
        return 0;
    }
    let mut bytes = 0u64;
    let mut len = m;
    while len > 1 {
        let pairs = len / 2;
        let mut w = 0usize;
        for t in 0..pairs {
            let a = nnzs[2 * t];
            let b = nnzs[2 * t + 1];
            bytes += message_cost(b, dim, allow_f16);
            nnzs[w] = (a + b).min(dim);
            w += 1;
        }
        if len % 2 == 1 {
            nnzs[w] = nnzs[len - 1];
            w += 1;
        }
        len = w;
    }
    if broadcast {
        // the merged root retraces the tree, one message per edge
        bytes += (m as u64 - 1) * message_cost(nnzs[0], dim, allow_f16);
    }
    bytes
}

/// Estimate the total bytes a full tree exchange (reduce + per-edge
/// broadcast) of contributions with the given per-machine `nnzs` (over
/// logical length `dim`) would charge, using the lossless codecs' cost
/// model (`min(nnz · 8, dim · 4)` per message). A conservative,
/// deterministic upper bound — see [`TreeByteEstimator`] for the
/// EWMA-sharpened variant the solver's strategy pick uses. `nnzs` is a
/// caller-reused scratch buffer and is clobbered by the dry tree walk.
pub fn estimate_tree_bytes(nnzs: &mut Vec<usize>, dim: usize) -> u64 {
    tree_walk_bytes(nnzs, dim, true, false)
}

/// One dry-walk prediction: the raw upper bound and the EWMA-sharpened
/// estimate actually compared by the strategy pick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ByteEstimate {
    /// The `nnz_a + nnz_b` upper-bound walk (what [`TreeByteEstimator::observe`]
    /// normalizes observations against).
    pub upper: u64,
    /// `upper` scaled by the EWMA of observed/upper ratios.
    pub predicted: u64,
}

/// EWMA smoothing for observed/upper byte ratios (≈ the last ~8
/// observations dominate).
const BYTE_EWMA_ALPHA: f64 = 0.25;
/// Shrink-factor clamp: guards against degenerate observations (an all-zero
/// iteration, a pathological f16 approximation) poisoning the estimator.
const SHRINK_MIN: f64 = 0.05;
const SHRINK_MAX: f64 = 1.5;

/// The sharpened tree-byte estimator behind the automatic reduce-Δm vs
/// allgather-Δβ pick. The raw `nnz_a + nnz_b` walk ignores support overlap
/// between machines (heavy for example-space Δm payloads) and the
/// delta-varint codec, which made the auto pick miss near the crossover
/// (ROADMAP open item). This estimator
///
/// * models the *charged* flow shape: a full reduce + broadcast for Δm,
///   a gather-only reduce for Δβ under worker-held shards
///   (`include_broadcast = false` drops the `(M-1) · root` term),
/// * admits the delta-varint codec's typical `nnz · 3` message size when
///   the policy allows f16 for the class, and
/// * keeps an EWMA of observed/upper-bound byte ratios from the exchanges
///   that actually ran, multiplying future upper bounds by it.
///
/// The state is two f64s, deterministic given the trajectory, and is
/// checkpointed (`Checkpoint::est_shrink`) so a resumed fit reproduces the
/// uninterrupted run's strategy picks — and therefore its `comm_bytes`
/// ledger — bit-for-bit.
#[derive(Debug, Clone)]
pub struct TreeByteEstimator {
    include_broadcast: bool,
    shrink: f64,
}

impl TreeByteEstimator {
    pub fn new(include_broadcast: bool) -> Self {
        Self { include_broadcast, shrink: 1.0 }
    }

    /// Current EWMA shrink factor (1.0 until the first observation).
    pub fn shrink(&self) -> f64 {
        self.shrink
    }

    /// Restore a checkpointed shrink factor.
    pub fn set_shrink(&mut self, shrink: f64) {
        self.shrink = shrink.clamp(SHRINK_MIN, SHRINK_MAX);
    }

    /// Dry-walk prediction for per-machine `nnzs` over logical length
    /// `dim`. `allow_f16` admits the lossy codec's typical size (pass the
    /// policy's eligibility for the message class). `nnzs` is clobbered.
    pub fn estimate(&self, nnzs: &mut [usize], dim: usize, allow_f16: bool) -> ByteEstimate {
        let upper = tree_walk_bytes(nnzs, dim, self.include_broadcast, allow_f16);
        let predicted = ((upper as f64) * self.shrink).round() as u64;
        ByteEstimate { upper, predicted }
    }

    /// Feed back what an exchange actually charged against the upper bound
    /// its estimate reported.
    pub fn observe(&mut self, upper: u64, actual: u64) {
        if upper == 0 {
            return;
        }
        let ratio = (actual as f64 / upper as f64).clamp(SHRINK_MIN, SHRINK_MAX);
        self.shrink = BYTE_EWMA_ALPHA * ratio + (1.0 - BYTE_EWMA_ALPHA) * self.shrink;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_matches_actual_bytes_on_disjoint_contributions() {
        // disjoint supports: the nnz upper bound is exact, so the estimate
        // must equal what the charged exchange actually moves
        let dim = 10_000usize;
        let m = 4usize;
        let contribs: Vec<SparseVec> = (0..m)
            .map(|k| {
                let mut v = SparseVec::new(dim);
                for t in 0..50u32 {
                    v.push(t * 80 + k as u32, (k + 1) as f32);
                }
                v
            })
            .collect();
        let mut nnzs: Vec<usize> = contribs.iter().map(|c| c.nnz()).collect();
        let est = estimate_tree_bytes(&mut nnzs, dim);

        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let refs: Vec<&SparseVec> = contribs.iter().collect();
        let ctx = CommCtx {
            ledger: &ledger,
            policy: CodecPolicy::lossless(),
            class: MessageClass::Beta,
            exec: &SerialExecutor,
            charge: true,
            broadcast: true,
        };
        let o = ar.exchange(m, &|k| refs[k], dim, &ctx, &mut scratch, &mut out);
        assert_eq!(est, o.bytes_moved);
        assert_eq!(out.nnz(), 200);
    }

    #[test]
    fn gather_only_walk_drops_exactly_the_broadcast_term() {
        // disjoint 50-nnz contributions from 4 machines: reduce edges move
        // 50 + 50 + 100 entries, the root (200) would retrace 3 edges
        let mut nnzs = vec![50usize, 50, 50, 50];
        let full = estimate_tree_bytes(&mut nnzs.clone(), 100_000);
        let gather = TreeByteEstimator::new(false)
            .estimate(&mut nnzs, 100_000, false)
            .upper;
        assert_eq!(full, gather + 3 * sparse_wire_bytes(200));
        assert_eq!(gather, sparse_wire_bytes(50 + 50 + 100));
    }

    #[test]
    fn estimator_ewma_tracks_observed_overlap() {
        let mut est = TreeByteEstimator::new(true);
        assert_eq!(est.shrink(), 1.0);
        let mut nnzs = vec![100usize; 4];
        let e = est.estimate(&mut nnzs, 1_000_000, false);
        assert_eq!(e.upper, e.predicted, "no observations yet");
        // heavy overlap: the exchange kept moving half the upper bound
        for _ in 0..32 {
            est.observe(e.upper, e.upper / 2);
        }
        assert!(
            (est.shrink() - 0.5).abs() < 0.02,
            "EWMA should converge toward the observed ratio, got {}",
            est.shrink()
        );
        let mut nnzs = vec![100usize; 4];
        let sharpened = est.estimate(&mut nnzs, 1_000_000, false);
        assert_eq!(sharpened.upper, e.upper, "upper bound is observation-free");
        assert!(sharpened.predicted < e.predicted);
        // zero-byte observations are ignored; ratios are clamped
        est.observe(0, 123);
        est.set_shrink(99.0);
        assert!(est.shrink() <= 1.5);
        est.set_shrink(1e-9);
        assert!(est.shrink() >= 0.05);
    }

    #[test]
    fn f16_eligibility_caps_the_message_cost_model() {
        // 100-nnz message over a large dim: sparse = 800, f16 typical = 300
        let mut nnzs = vec![100usize, 100];
        let lossless = TreeByteEstimator::new(false)
            .estimate(&mut nnzs, 1_000_000, false)
            .upper;
        let mut nnzs = vec![100usize, 100];
        let lossy = TreeByteEstimator::new(false)
            .estimate(&mut nnzs, 1_000_000, true)
            .upper;
        assert_eq!(lossless, 800);
        assert_eq!(lossy, 300);
    }

    #[test]
    fn estimate_is_zero_for_single_machine_and_scales_with_payload() {
        assert_eq!(estimate_tree_bytes(&mut vec![100], 1000), 0);
        let small = estimate_tree_bytes(&mut vec![10, 10, 10, 10], 100_000);
        let large = estimate_tree_bytes(&mut vec![1000, 1000, 1000, 1000], 100_000);
        assert!(large > small);
        // payload denser than 50%: dense cost caps every message
        let capped = estimate_tree_bytes(&mut vec![90, 90], 100);
        assert_eq!(capped, 400 + 400); // one reduce edge + one broadcast edge
    }

    #[test]
    fn bracket_forest_and_charge_replay_match_the_staged_engine() {
        use crate::cluster::allreduce::merge_sorted_into;
        use crate::cluster::network::NetworkLedger;
        use std::collections::HashMap;
        for m in [2usize, 3, 5, 8] {
            let dim = 4_000usize;
            // overlapping supports: merged nnz < summed nnz, so the replay
            // genuinely needs the per-edge accumulated sizes
            let contribs: Vec<SparseVec> = (0..m)
                .map(|k| {
                    SparseVec::from_dense(
                        &(0..dim)
                            .map(|i| {
                                if (i + k) % 13 == 0 { (i + 2 * k) as f32 * 0.5 } else { 0.0 }
                            })
                            .collect::<Vec<f32>>(),
                    )
                })
                .collect();
            let refs: Vec<&SparseVec> = contribs.iter().collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let staged_ledger = NetworkLedger::new();
            let mut scratch = AllReduceScratch::default();
            let mut out = SparseVec::new(0);
            let ctx = CommCtx {
                ledger: &staged_ledger,
                policy: CodecPolicy::lossless(),
                class: MessageClass::Margins,
                exec: &SerialExecutor,
                charge: true,
                broadcast: true,
            };
            let o = ar.exchange(m, &|k| refs[k], dim, &ctx, &mut scratch, &mut out);

            // simulate the physical tree: every machine folds its bracket
            // children's accumulated payloads in merge order; children are
            // always higher-numbered than their parent, so a descending
            // sweep folds every subtree before its edge fires
            let children = bracket_children(m);
            let parent = bracket_parent(m);
            assert_eq!(parent[0], None);
            for (a, kids) in children.iter().enumerate() {
                for &b in kids {
                    assert!(b as usize > a, "child {b} must outnumber parent {a}");
                    assert_eq!(parent[b as usize], Some(a as u32));
                }
            }
            let mut acc_idx: Vec<Vec<u32>> =
                contribs.iter().map(|c| c.indices.clone()).collect();
            let mut acc_val: Vec<Vec<f64>> = contribs
                .iter()
                .map(|c| c.values.iter().map(|&v| v as f64).collect())
                .collect();
            let mut edge_nnzs: HashMap<(u32, u32), usize> = HashMap::new();
            for a in (0..m).rev() {
                for &b in &children[a] {
                    edge_nnzs.insert((a as u32, b), acc_idx[b as usize].len());
                    let (mut oi, mut ov) = (Vec::new(), Vec::new());
                    let (ai, av) = (&acc_idx[a], &acc_val[a]);
                    merge_sorted_into(
                        ai,
                        av,
                        &acc_idx[b as usize],
                        &acc_val[b as usize],
                        &mut oi,
                        &mut ov,
                    );
                    acc_idx[a] = oi;
                    acc_val[a] = ov;
                }
            }
            assert_eq!(edge_nnzs.len(), m - 1, "one edge per non-root machine");
            let mut root_sv = SparseVec::new(dim);
            for (i, &x) in acc_idx[0].iter().zip(&acc_val[0]) {
                root_sv.push(*i, x as f32);
            }
            assert_eq!(root_sv, out, "m={m}: physical merges must match staged root");

            // the nnz-metadata replay reproduces the staged ledger exactly
            let replay_ledger = NetworkLedger::new();
            let r = replay_tree_charges(
                &NetworkModel::gigabit(),
                m,
                dim,
                &replay_ledger,
                &CodecPolicy::lossless(),
                MessageClass::Margins,
                true,
                true,
                &mut |a, b| Ok(edge_nnzs[&(a, b)]),
                acc_idx[0].len(),
            )
            .unwrap();
            assert_eq!(r.bytes_moved, o.bytes_moved, "m={m}");
            assert_eq!(r.rounds, o.rounds);
            assert_eq!(replay_ledger.total_bytes(), staged_ledger.total_bytes());
            assert_eq!(replay_ledger.total_messages(), staged_ledger.total_messages());
            assert_eq!(r.simulated_secs.to_bits(), o.simulated_secs.to_bits());

            // gather-only (broadcast = false) drops exactly the retrace
            let gather_ledger = NetworkLedger::new();
            let g = replay_tree_charges(
                &NetworkModel::gigabit(),
                m,
                dim,
                &gather_ledger,
                &CodecPolicy::lossless(),
                MessageClass::Beta,
                true,
                false,
                &mut |a, b| Ok(edge_nnzs[&(a, b)]),
                acc_idx[0].len(),
            )
            .unwrap();
            assert!(g.bytes_moved < r.bytes_moved);

            // an f16-eligible class cannot be replayed from nnz alone
            let lossy = CodecPolicy { f16_margins: true, ..CodecPolicy::default() };
            assert!(replay_tree_charges(
                &NetworkModel::gigabit(),
                m,
                dim,
                &NetworkLedger::new(),
                &lossy,
                MessageClass::Margins,
                true,
                true,
                &mut |a, b| Ok(edge_nnzs[&(a, b)]),
                acc_idx[0].len(),
            )
            .is_err());
        }
    }

    #[test]
    fn allgather_matches_allreduce_bitwise() {
        let dim = 500usize;
        let contribs: Vec<SparseVec> = (0..5)
            .map(|k| {
                SparseVec::from_dense(
                    &(0..dim)
                        .map(|i| if (i + k) % 17 == 0 { (i + k) as f32 * 0.25 } else { 0.0 })
                        .collect::<Vec<f32>>(),
                )
            })
            .collect();
        let refs: Vec<&SparseVec> = contribs.iter().collect();
        let model = NetworkModel::gigabit();
        let run = |coll: &dyn Collective| {
            let ledger = NetworkLedger::new();
            let mut scratch = AllReduceScratch::default();
            let mut out = SparseVec::new(0);
            let ctx = CommCtx {
                ledger: &ledger,
                policy: CodecPolicy::lossless(),
                class: MessageClass::Margins,
                exec: &SerialExecutor,
                charge: true,
                broadcast: true,
            };
            let o = coll.exchange(refs.len(), &|k| refs[k], dim, &ctx, &mut scratch, &mut out);
            (out, o.bytes_moved)
        };
        let ar = TreeAllReduce::new(model);
        let ag = AllGather::new(model);
        let (a, a_bytes) = run(&ar);
        let (b, b_bytes) = run(&ag);
        assert_eq!(a, b, "same tree, same merges, same result");
        assert_eq!(a_bytes, b_bytes);
        assert_eq!(ar.name(), "tree-allreduce");
        assert_eq!(ag.name(), "allgather");
    }
}
