//! The pluggable communication subsystem every Δ-exchange routes through:
//! a [`Collective`] trait over the simulated network (implemented by the
//! tree [`TreeAllReduce`] and the new [`AllGather`]), a [`TaskExecutor`]
//! abstraction that lets tree-node merges run off the calling thread (the
//! solver plugs its `WorkerPool` in, so the leader thread never performs
//! merge work), and the byte-cost estimator the `FitDriver` uses to choose
//! between the reduce-Δm and allgather-Δβ exchange strategies.
//!
//! Wire formats and per-message codec selection live in
//! [`crate::cluster::codec`]; the shared tree engine (deterministic
//! pairwise merge order, per-edge charging) lives in
//! [`crate::cluster::allreduce`].

use crate::cluster::allreduce::{run_sparse_exchange, AllReduceOutcome, AllReduceScratch};
use crate::cluster::codec::{dense_wire_bytes, sparse_wire_bytes, CodecPolicy, MessageClass};
use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::cluster::TreeAllReduce;
use crate::data::sparse::SparseVec;

/// One unit of off-thread work (a tree-node merge).
pub type Job = Box<dyn FnOnce() + Send>;

/// Runs a batch of independent jobs to completion. `run_all` must not
/// return until every job has executed — the collectives rely on it as a
/// per-round barrier.
pub trait TaskExecutor {
    fn run_all(&self, jobs: Vec<Job>);
}

/// Executes jobs inline on the calling thread (tests, compat wrappers, and
/// callers without a worker pool).
#[derive(Debug, Default)]
pub struct SerialExecutor;

impl TaskExecutor for SerialExecutor {
    fn run_all(&self, jobs: Vec<Job>) {
        for job in jobs {
            job();
        }
    }
}

/// Shared context for one collective call: where to charge bytes, which
/// codecs the policy allows for this message class, who runs the merges,
/// and whether the wire is charged at all (`charge = false` models a
/// leader-local recomputation — same deterministic merge, zero bytes).
pub struct CommCtx<'a> {
    pub ledger: &'a NetworkLedger,
    pub policy: CodecPolicy,
    pub class: MessageClass,
    pub exec: &'a dyn TaskExecutor,
    pub charge: bool,
}

/// A collective over M per-machine sparse contributions: every machine
/// (and the leader) ends with the merged vector in `out`. Overlapping
/// indices sum in `f64`, in a fixed pairwise tree order, so any two
/// collectives (and any executor) produce bit-identical results.
pub trait Collective {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome;

    fn name(&self) -> &'static str;
}

impl Collective for TreeAllReduce {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        run_sparse_exchange(&self.model, m, contrib, dim, ctx, scratch, out)
    }

    fn name(&self) -> &'static str {
        "tree-allreduce"
    }
}

/// AllGather over the simulated network: gather the M contributions up the
/// binary tree, broadcast the union back down — after which every machine
/// holds the full merged vector. The intended payload is the machines'
/// *disjoint* Δβ shards (a feature partition never overlaps), where gather
/// is pure concatenation; overlapping indices, if any, sum exactly like
/// the reduce, so the result — and the per-edge charge — is bit-identical
/// to [`TreeAllReduce::exchange`](Collective::exchange) (pinned by
/// `allgather_matches_allreduce_bitwise`). The distinct type exists for
/// the semantic contract (every machine ends holding the full vector,
/// which is what lets the Δm reduce be skipped entirely) and as the
/// extension point for true ring/recursive-doubling allgathers.
#[derive(Debug)]
pub struct AllGather {
    pub model: NetworkModel,
}

impl AllGather {
    pub fn new(model: NetworkModel) -> Self {
        Self { model }
    }
}

impl Collective for AllGather {
    fn exchange<'a>(
        &self,
        m: usize,
        contrib: &dyn Fn(usize) -> &'a SparseVec,
        dim: usize,
        ctx: &CommCtx<'_>,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        run_sparse_exchange(&self.model, m, contrib, dim, ctx, scratch, out)
    }

    fn name(&self) -> &'static str {
        "allgather"
    }
}

/// Estimate the total bytes a tree exchange of contributions with the
/// given per-machine `nnzs` (over logical length `dim`) would charge, using
/// the lossless codecs' cost model (`min(nnz · 8, dim · 4)` per message).
/// Merged-node sizes are upper-bounded by `nnz_a + nnz_b` (overlap is
/// unknown before merging), so this over-estimates overlapping payloads —
/// a conservative, deterministic input to the strategy choice. `nnzs` is a
/// caller-reused scratch buffer and is clobbered by the dry tree walk.
pub fn estimate_tree_bytes(nnzs: &mut Vec<usize>, dim: usize) -> u64 {
    let m = nnzs.len();
    if m <= 1 {
        return 0;
    }
    let mut bytes = 0u64;
    let mut len = m;
    while len > 1 {
        let pairs = len / 2;
        let mut w = 0usize;
        for t in 0..pairs {
            let a = nnzs[2 * t];
            let b = nnzs[2 * t + 1];
            bytes += sparse_wire_bytes(b).min(dense_wire_bytes(dim));
            nnzs[w] = (a + b).min(dim);
            w += 1;
        }
        if len % 2 == 1 {
            nnzs[w] = nnzs[len - 1];
            w += 1;
        }
        len = w;
    }
    // broadcast: the merged root retraces the tree, one message per edge
    let root = sparse_wire_bytes(nnzs[0]).min(dense_wire_bytes(dim));
    bytes + (m as u64 - 1) * root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_matches_actual_bytes_on_disjoint_contributions() {
        // disjoint supports: the nnz upper bound is exact, so the estimate
        // must equal what the charged exchange actually moves
        let dim = 10_000usize;
        let m = 4usize;
        let contribs: Vec<SparseVec> = (0..m)
            .map(|k| {
                let mut v = SparseVec::new(dim);
                for t in 0..50u32 {
                    v.push(t * 80 + k as u32, (k + 1) as f32);
                }
                v
            })
            .collect();
        let mut nnzs: Vec<usize> = contribs.iter().map(|c| c.nnz()).collect();
        let est = estimate_tree_bytes(&mut nnzs, dim);

        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let refs: Vec<&SparseVec> = contribs.iter().collect();
        let ctx = CommCtx {
            ledger: &ledger,
            policy: CodecPolicy::lossless(),
            class: MessageClass::Beta,
            exec: &SerialExecutor,
            charge: true,
        };
        let o = ar.exchange(m, &|k| refs[k], dim, &ctx, &mut scratch, &mut out);
        assert_eq!(est, o.bytes_moved);
        assert_eq!(out.nnz(), 200);
    }

    #[test]
    fn estimate_is_zero_for_single_machine_and_scales_with_payload() {
        assert_eq!(estimate_tree_bytes(&mut vec![100], 1000), 0);
        let small = estimate_tree_bytes(&mut vec![10, 10, 10, 10], 100_000);
        let large = estimate_tree_bytes(&mut vec![1000, 1000, 1000, 1000], 100_000);
        assert!(large > small);
        // payload denser than 50%: dense cost caps every message
        let capped = estimate_tree_bytes(&mut vec![90, 90], 100);
        assert_eq!(capped, 400 + 400); // one reduce edge + one broadcast edge
    }

    #[test]
    fn allgather_matches_allreduce_bitwise() {
        let dim = 500usize;
        let contribs: Vec<SparseVec> = (0..5)
            .map(|k| {
                SparseVec::from_dense(
                    &(0..dim)
                        .map(|i| if (i + k) % 17 == 0 { (i + k) as f32 * 0.25 } else { 0.0 })
                        .collect::<Vec<f32>>(),
                )
            })
            .collect();
        let refs: Vec<&SparseVec> = contribs.iter().collect();
        let model = NetworkModel::gigabit();
        let run = |coll: &dyn Collective| {
            let ledger = NetworkLedger::new();
            let mut scratch = AllReduceScratch::default();
            let mut out = SparseVec::new(0);
            let ctx = CommCtx {
                ledger: &ledger,
                policy: CodecPolicy::lossless(),
                class: MessageClass::Margins,
                exec: &SerialExecutor,
                charge: true,
            };
            let o = coll.exchange(refs.len(), &|k| refs[k], dim, &ctx, &mut scratch, &mut out);
            (out, o.bytes_moved)
        };
        let ar = TreeAllReduce::new(model);
        let ag = AllGather::new(model);
        let (a, a_bytes) = run(&ar);
        let (b, b_bytes) = run(&ag);
        assert_eq!(a, b, "same tree, same merges, same result");
        assert_eq!(a_bytes, b_bytes);
        assert_eq!(ar.name(), "tree-allreduce");
        assert_eq!(ag.name(), "allgather");
    }
}
