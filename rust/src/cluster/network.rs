//! Byte-accounted network model. The paper's testbed is 16 blade servers on
//! Gigabit Ethernet; we model each transfer as `latency + bytes/bandwidth`
//! and keep a ledger so benchmarks can report simulated network time and
//! total volume next to wall-clock compute time.
//!
//! Callers charge the ledger with the *actual payload* of each message —
//! the exact encoded size under the wire codec the byte-cost model picked
//! for that edge (see `cluster::codec`), not a nominal dense `dim · 4` —
//! so `comm_bytes` and simulated seconds reward sparse and compressed
//! updates the way a real cluster would. Broadcast fan-out is charged per
//! edge (`M - 1` messages), with levels concurrent in the time model.

use std::sync::atomic::{AtomicU64, Ordering};

/// Link parameters (defaults: GigE — 1 Gbit/s, 100 µs one-way latency).
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    pub bandwidth_bytes_per_sec: f64,
    pub latency_sec: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self { bandwidth_bytes_per_sec: 125e6, latency_sec: 100e-6 }
    }
}

impl NetworkModel {
    pub fn gigabit() -> Self {
        Self::default()
    }

    pub fn ten_gigabit() -> Self {
        Self { bandwidth_bytes_per_sec: 1.25e9, latency_sec: 50e-6 }
    }

    /// Simulated seconds for one point-to-point message.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_sec + bytes as f64 / self.bandwidth_bytes_per_sec
    }
}

/// Thread-safe accumulating ledger of simulated traffic.
///
/// Supervision traffic (heartbeats, re-admission handshakes — the
/// [`MessageClass::Recovery`](crate::cluster::codec::MessageClass::Recovery)
/// class) accumulates in its own bucket: `total_bytes()` stays the honest
/// algorithmic comm volume the paper's cost claims are benchmarked on, and
/// a recovered fit reproduces it bit-for-bit while `recovery_bytes()`
/// reports what the failure cost on top.
#[derive(Debug, Default)]
pub struct NetworkLedger {
    bytes: AtomicU64,
    messages: AtomicU64,
    /// nanoseconds of simulated time (atomics don't do f64)
    sim_nanos: AtomicU64,
    recovery_bytes: AtomicU64,
    recovery_messages: AtomicU64,
}

impl NetworkLedger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, model: &NetworkModel, bytes: u64) -> f64 {
        let secs = model.transfer_secs(bytes);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.sim_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn simulated_secs(&self) -> f64 {
        self.sim_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Charge one supervision-class frame (heartbeat, re-admission
    /// handshake). Kept out of `total_bytes` / simulated time so recovery
    /// never perturbs the algorithmic comm ledger.
    pub fn record_recovery(&self, bytes: u64) {
        self.recovery_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.recovery_messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn recovery_bytes(&self) -> u64 {
        self.recovery_bytes.load(Ordering::Relaxed)
    }

    pub fn recovery_messages(&self) -> u64 {
        self.recovery_messages.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.bytes.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
        self.sim_nanos.store(0, Ordering::Relaxed);
        self.recovery_bytes.store(0, Ordering::Relaxed);
        self.recovery_messages.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let m = NetworkModel::gigabit();
        let t1 = m.transfer_secs(125_000_000); // 1 s of payload
        assert!((t1 - 1.0001).abs() < 1e-6);
        let t0 = m.transfer_secs(0);
        assert!((t0 - 100e-6).abs() < 1e-12);
    }

    #[test]
    fn ledger_accumulates_across_threads() {
        let ledger = NetworkLedger::new();
        let model = NetworkModel::gigabit();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        ledger.record(&model, 1_000);
                    }
                });
            }
        });
        assert_eq!(ledger.total_bytes(), 400_000);
        assert_eq!(ledger.total_messages(), 400);
        assert!(ledger.simulated_secs() > 0.0);
        ledger.reset();
        assert_eq!(ledger.total_bytes(), 0);
    }

    #[test]
    fn recovery_traffic_has_its_own_bucket() {
        let ledger = NetworkLedger::new();
        let model = NetworkModel::gigabit();
        ledger.record(&model, 100);
        ledger.record_recovery(7);
        ledger.record_recovery(5);
        // the algorithmic ledger is untouched by supervision traffic
        assert_eq!(ledger.total_bytes(), 100);
        assert_eq!(ledger.total_messages(), 1);
        assert_eq!(ledger.recovery_bytes(), 12);
        assert_eq!(ledger.recovery_messages(), 2);
        ledger.reset();
        assert_eq!(ledger.recovery_bytes(), 0);
        assert_eq!(ledger.recovery_messages(), 0);
    }
}
