//! Wire codecs for the simulated cluster: how one sparse message is laid
//! out on the wire, what it costs in bytes, and (for the lossy codec) what
//! it does to the values. Every edge of every collective picks its codec
//! per message through [`CodecPolicy::pick`] — a byte-cost model that
//! replaces the old single hard-coded 0.25 density threshold.
//!
//! Three codecs:
//!
//! * [`WireCodec::DenseF32`] — the classic dense vector: `dim · 4` bytes,
//!   position is implicit. Cheapest once a message is denser than 50%.
//! * [`WireCodec::SparseU32F32`] — the PR-1 sparse format: `nnz · (4 + 4)`
//!   bytes (`u32` index + `f32` value per entry).
//! * [`WireCodec::DeltaVarintF16`] — delta-encoded indices as LEB128
//!   varints (sorted-unique indices make the gaps small, so most gaps fit
//!   one byte) plus IEEE 754 half-precision values: typically `nnz · 3`
//!   bytes, a further ~2.6× under the sparse format. **Lossy** in the
//!   values (relative error ≤ 2⁻¹¹ in the f16 normal range), so it is
//!   off by default and only eligible where the policy explicitly allows
//!   it for the message's [`MessageClass`] — never for β-carrying
//!   messages unless `f16_beta` is set.
//!
//! The cost functions ([`WireCodec::encoded_bytes`]) are exact: they equal
//! `encode(msg).len()` byte for byte (pinned by `tests/wire_codec.rs`), so
//! the ledger charges precisely what a real serializer would move. The hot
//! path charges costs without materializing buffers; `encode`/`decode`
//! exist for tests and for real exporters.

use crate::data::sparse::{SparseVec, SPARSE_ENTRY_BYTES};
use crate::error::{DlrError, Result};

// ---------------------------------------------------------------------------
// f16 conversion (no `half` crate in the vendored set)
// ---------------------------------------------------------------------------

/// Convert an `f32` to IEEE 754 binary16 bits, rounding to nearest-even.
/// Overflow goes to ±inf, underflow to (sub)normals then ±0.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // inf stays inf; NaN keeps a quiet payload bit
        return if abs > 0x7F80_0000 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let exp = (abs >> 23) as i32 - 127 + 15;
    let mant = abs & 0x007F_FFFF;
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // too small for a subnormal: rounds to zero
        }
        // subnormal: shift the (implicit-1) mantissa into place
        let m = mant | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half & 1) == 1) {
            half + 1
        } else {
            half
        };
        return sign | rounded as u16;
    }
    let half = ((exp as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1FFF;
    // a mantissa carry overflows into the exponent field, which is exactly
    // the right rounding behavior (up to and including carry into inf)
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        half + 1
    } else {
        half
    };
    sign | rounded as u16
}

/// Convert IEEE 754 binary16 bits back to an `f32` (exact — every f16 value
/// is representable in f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp != 0 {
        sign | ((exp + 112) << 23) | (mant << 13)
    } else if mant == 0 {
        sign // ±0
    } else {
        // subnormal: normalize (value = mant · 2^-24)
        let p = 31 - mant.leading_zeros(); // MSB position, 0..=9
        let exp32 = p + 103; // (p - 24) + 127
        let mant32 = (mant << (23 - p)) & 0x007F_FFFF;
        sign | (exp32 << 23) | mant32
    };
    f32::from_bits(bits)
}

/// Round a value through the f16 wire (what the lossy codec does to every
/// payload value).
pub fn f16_round_trip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Quantize a slice of f64 tree accumulators through the f16 wire, in
/// place — applied to a message's payload when the cost model picks
/// [`WireCodec::DeltaVarintF16`] for its edge.
pub fn quantize_f16_f64(vals: &mut [f64]) {
    for v in vals.iter_mut() {
        *v = f16_round_trip(*v as f32) as f64;
    }
}

// ---------------------------------------------------------------------------
// LEB128 varints
// ---------------------------------------------------------------------------

/// Encoded length of one LEB128 varint.
pub fn varint_len(mut v: u32) -> u64 {
    let mut n = 1u64;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

fn write_varint(out: &mut Vec<u8>, mut v: u32) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let mut v = 0u32;
    let mut shift = 0u32;
    loop {
        let b = *bytes
            .get(*pos)
            .ok_or_else(|| DlrError::parse("wire", "truncated varint"))?;
        *pos += 1;
        let chunk = (b & 0x7F) as u32;
        // a 5th byte may only carry the top 4 bits of a u32; anything more
        // (or a 6th byte) is an overflow, not silent truncation
        if shift >= 32 || (shift == 28 && chunk > 0x0F) {
            return Err(DlrError::parse("wire", "varint overflows u32"));
        }
        v |= chunk << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

// ---------------------------------------------------------------------------
// Cost functions (exact — equal to encode().len())
// ---------------------------------------------------------------------------

/// Dense `f32` wire size: `dim · 4` bytes.
pub fn dense_wire_bytes(dim: usize) -> u64 {
    dim as u64 * 4
}

/// Sparse `u32 + f32` wire size: `nnz · 8` bytes.
pub fn sparse_wire_bytes(nnz: usize) -> u64 {
    nnz as u64 * SPARSE_ENTRY_BYTES
}

/// Delta-varint + f16 wire size for a sorted-unique index list:
/// `Σ varint_len(gap) + 2 · nnz` bytes.
pub fn delta_varint_f16_wire_bytes(indices: &[u32]) -> u64 {
    let mut bytes = 0u64;
    let mut prev = 0u32;
    for &i in indices {
        bytes += varint_len(i - prev) + 2;
        prev = i;
    }
    bytes
}

// ---------------------------------------------------------------------------
// Codecs
// ---------------------------------------------------------------------------

/// One wire layout for a sparse message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    /// Positional `f32` values, `dim · 4` bytes.
    DenseF32,
    /// `(u32 index, f32 value)` entries, `nnz · 8` bytes.
    SparseU32F32,
    /// LEB128 index gaps + f16 values — lossy, opt-in per message class.
    DeltaVarintF16,
}

impl WireCodec {
    pub fn name(&self) -> &'static str {
        match self {
            WireCodec::DenseF32 => "dense-f32",
            WireCodec::SparseU32F32 => "sparse-u32f32",
            WireCodec::DeltaVarintF16 => "delta-varint-f16",
        }
    }

    /// Does decode(encode(msg)) reproduce the values bit for bit?
    pub fn is_lossless(&self) -> bool {
        !matches!(self, WireCodec::DeltaVarintF16)
    }

    /// Exact wire size of `msg` under this codec — byte-for-byte equal to
    /// `self.encode(msg).len()` (the ledger charges this without
    /// materializing the buffer).
    pub fn encoded_bytes(&self, msg: &SparseVec) -> u64 {
        match self {
            WireCodec::DenseF32 => dense_wire_bytes(msg.dim),
            WireCodec::SparseU32F32 => sparse_wire_bytes(msg.nnz()),
            WireCodec::DeltaVarintF16 => delta_varint_f16_wire_bytes(&msg.indices),
        }
    }

    /// Serialize `msg`. Explicit zero entries survive the sparse codecs but
    /// are (by construction) dropped by a dense round-trip.
    pub fn encode(&self, msg: &SparseVec) -> Vec<u8> {
        match self {
            WireCodec::DenseF32 => {
                let mut out = vec![0u8; msg.dim * 4];
                for (i, v) in msg.iter() {
                    let at = i as usize * 4;
                    out[at..at + 4].copy_from_slice(&v.to_le_bytes());
                }
                out
            }
            WireCodec::SparseU32F32 => {
                let mut out = Vec::with_capacity(msg.nnz() * 8);
                for (i, v) in msg.iter() {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            WireCodec::DeltaVarintF16 => {
                let mut out = Vec::with_capacity(msg.nnz() * 3);
                let mut prev = 0u32;
                for (i, v) in msg.iter() {
                    write_varint(&mut out, i - prev);
                    out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                    prev = i;
                }
                out
            }
        }
    }

    /// Deserialize a codec-produced buffer back into a message of logical
    /// length `dim`.
    pub fn decode(&self, bytes: &[u8], dim: usize) -> Result<SparseVec> {
        let mut out = SparseVec::new(dim);
        match self {
            WireCodec::DenseF32 => {
                if bytes.len() != dim * 4 {
                    return Err(DlrError::parse(
                        "wire",
                        format!("dense payload is {} bytes, want {}", bytes.len(), dim * 4),
                    ));
                }
                for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                    let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                    if v != 0.0 {
                        out.push(i as u32, v);
                    }
                }
            }
            WireCodec::SparseU32F32 => {
                if bytes.len() % 8 != 0 {
                    return Err(DlrError::parse("wire", "sparse payload not a multiple of 8"));
                }
                for chunk in bytes.chunks_exact(8) {
                    let i = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                    let v = f32::from_le_bytes([chunk[4], chunk[5], chunk[6], chunk[7]]);
                    if i as usize >= dim {
                        return Err(DlrError::parse("wire", format!("index {i} >= dim {dim}")));
                    }
                    // uphold the sorted-unique invariant instead of handing
                    // a malformed payload to SparseVec::push
                    if out.indices.last().is_some_and(|&last| last >= i) {
                        return Err(DlrError::parse("wire", "indices not strictly ascending"));
                    }
                    out.push(i, v);
                }
            }
            WireCodec::DeltaVarintF16 => {
                let mut pos = 0usize;
                let mut acc = 0u32;
                let mut first = true;
                while pos < bytes.len() {
                    let gap = read_varint(bytes, &mut pos)?;
                    if pos + 2 > bytes.len() {
                        return Err(DlrError::parse("wire", "truncated f16 value"));
                    }
                    let h = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]);
                    pos += 2;
                    // a zero gap is only legal for the very first entry
                    // (absolute index 0); afterwards it would duplicate one
                    if !first && gap == 0 {
                        return Err(DlrError::parse("wire", "zero index gap"));
                    }
                    acc = acc
                        .checked_add(gap)
                        .ok_or_else(|| DlrError::parse("wire", "index overflows u32"))?;
                    if acc as usize >= dim {
                        return Err(DlrError::parse("wire", format!("index {acc} >= dim {dim}")));
                    }
                    out.push(acc, f16_bits_to_f32(h));
                    first = false;
                }
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Policy: which codecs a message may use
// ---------------------------------------------------------------------------

/// What a message carries — the lossy codec is gated per class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageClass {
    /// Example-space Δmargins (Δβᵀx per machine).
    Margins,
    /// Feature-space Δβ — β-carrying, f16-ineligible unless explicitly
    /// enabled (quantizing the model update itself is rarely worth it).
    Beta,
    /// Supervision traffic — heartbeats, re-admission handshakes, rollback
    /// state pushes. Accounted in its own ledger bucket
    /// ([`crate::cluster::NetworkLedger::recovery_bytes`]) so failure
    /// recovery never pollutes the `comm_bytes` the paper's cost claims
    /// are benchmarked on; never f16 (state must move bit-exactly).
    Recovery,
}

/// Which codecs the cost model may choose from, per message class.
/// Defaults are fully lossless; `force_dense` reproduces the pre-sparsity
/// dense baseline (the `dense_allreduce` ablation).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CodecPolicy {
    /// Charge every message at the dense `dim · 4` rate (ablation baseline).
    pub force_dense: bool,
    /// Allow [`WireCodec::DeltaVarintF16`] for [`MessageClass::Margins`].
    pub f16_margins: bool,
    /// Allow [`WireCodec::DeltaVarintF16`] for [`MessageClass::Beta`].
    pub f16_beta: bool,
}

impl CodecPolicy {
    /// Lossless codecs only (the default production policy).
    pub fn lossless() -> Self {
        Self::default()
    }

    pub fn allows_f16(&self, class: MessageClass) -> bool {
        match class {
            MessageClass::Margins => self.f16_margins,
            MessageClass::Beta => self.f16_beta,
            MessageClass::Recovery => false,
        }
    }

    /// Pick the cheapest eligible codec for one message (sorted-unique
    /// `indices` over logical length `dim`) and return it with its exact
    /// byte cost. Ties prefer the sparse format; the result never costs
    /// more than the dense equivalent unless `force_dense` is set (where
    /// it *is* the dense equivalent).
    pub fn pick(&self, indices: &[u32], dim: usize, class: MessageClass) -> (WireCodec, u64) {
        let dense = dense_wire_bytes(dim);
        if self.force_dense {
            return (WireCodec::DenseF32, dense);
        }
        let sparse = sparse_wire_bytes(indices.len());
        let (mut best, mut cost) = if dense < sparse {
            (WireCodec::DenseF32, dense)
        } else {
            (WireCodec::SparseU32F32, sparse)
        };
        if self.allows_f16(class) {
            let delta = delta_varint_f16_wire_bytes(indices);
            if delta < cost {
                best = WireCodec::DeltaVarintF16;
                cost = delta;
            }
        }
        (best, cost)
    }

    /// Byte cost of one message knowing only its nnz — the leader-side
    /// replay of a physical-tree exchange charges edges from the senders'
    /// nnz metadata without ever seeing the index lists. Only valid when
    /// the class is f16-ineligible under this policy (the delta-varint
    /// cost depends on the actual index gaps): returns `None` when f16 is
    /// allowed, which is why `topology = tree` requires the lossless
    /// policy at config validation.
    pub fn cost_from_nnz(&self, nnz: usize, dim: usize, class: MessageClass) -> Option<u64> {
        if self.allows_f16(class) {
            return None;
        }
        let dense = dense_wire_bytes(dim);
        if self.force_dense {
            return Some(dense);
        }
        Some(dense.min(sparse_wire_bytes(nnz)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_specials_round_trip() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0] {
            assert_eq!(f16_round_trip(x), x, "{x} must be exactly representable");
        }
        assert_eq!(f16_round_trip(f32::INFINITY), f32::INFINITY);
        assert_eq!(f16_round_trip(f32::NEG_INFINITY), f32::NEG_INFINITY);
        // overflow clamps to inf, tiny values flush toward zero
        assert_eq!(f16_round_trip(1e6), f32::INFINITY);
        assert_eq!(f16_round_trip(1e-10), 0.0);
        assert!(f16_round_trip(f32::NAN).is_nan());
        // signed zero is preserved
        assert_eq!(f16_round_trip(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_relative_error_is_bounded_in_normal_range() {
        for k in 0..2000 {
            let x = (0.001 + k as f32 * 0.517) * if k % 2 == 0 { 1.0 } else { -1.0 };
            let back = f16_round_trip(x);
            let rel = ((back - x) / x).abs();
            assert!(rel <= 1.0 / 1024.0, "x = {x}: back = {back}, rel = {rel}");
        }
    }

    #[test]
    fn varint_lengths_match_written_bytes() {
        for v in [0u32, 1, 127, 128, 16_383, 16_384, 1 << 21, u32::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            assert_eq!(buf.len() as u64, varint_len(v), "v = {v}");
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn pick_prefers_cheapest_and_never_beats_dense_cap() {
        let dim = 100usize;
        let sparse_msg = SparseVec::from_dense(
            &(0..dim).map(|i| if i % 10 == 0 { 1.0 } else { 0.0 }).collect::<Vec<f32>>(),
        );
        let dense_msg = SparseVec::from_dense(&vec![1.0f32; dim]);
        let policy = CodecPolicy::lossless();
        let (c, cost) = policy.pick(&sparse_msg.indices, dim, MessageClass::Margins);
        assert_eq!(c, WireCodec::SparseU32F32);
        assert_eq!(cost, 80);
        let (c, cost) = policy.pick(&dense_msg.indices, dim, MessageClass::Margins);
        assert_eq!(c, WireCodec::DenseF32);
        assert_eq!(cost, 400);
        // f16 only when the class allows it
        let lossy = CodecPolicy { f16_margins: true, ..CodecPolicy::default() };
        let (c, cost) = lossy.pick(&sparse_msg.indices, dim, MessageClass::Margins);
        assert_eq!(c, WireCodec::DeltaVarintF16);
        assert!(cost < 80);
        let (c, _) = lossy.pick(&sparse_msg.indices, dim, MessageClass::Beta);
        assert_eq!(c, WireCodec::SparseU32F32, "beta messages stay lossless");
        // force_dense charges the dense rate regardless
        let forced = CodecPolicy { force_dense: true, ..CodecPolicy::default() };
        assert_eq!(
            forced.pick(&sparse_msg.indices, dim, MessageClass::Margins),
            (WireCodec::DenseF32, 400)
        );
    }

    #[test]
    fn cost_from_nnz_matches_pick_when_lossless() {
        let dim = 100usize;
        let sparse_msg = SparseVec::from_dense(
            &(0..dim).map(|i| if i % 10 == 0 { 1.0 } else { 0.0 }).collect::<Vec<f32>>(),
        );
        let dense_msg = SparseVec::from_dense(&vec![1.0f32; dim]);
        for policy in [
            CodecPolicy::lossless(),
            CodecPolicy { force_dense: true, ..CodecPolicy::default() },
        ] {
            for msg in [&sparse_msg, &dense_msg] {
                for class in [MessageClass::Margins, MessageClass::Beta] {
                    let (_, cost) = policy.pick(&msg.indices, dim, class);
                    assert_eq!(policy.cost_from_nnz(msg.nnz(), dim, class), Some(cost));
                }
            }
        }
        // f16-eligible classes cannot be replayed from nnz alone
        let lossy = CodecPolicy { f16_margins: true, ..CodecPolicy::default() };
        assert_eq!(lossy.cost_from_nnz(sparse_msg.nnz(), dim, MessageClass::Margins), None);
        assert!(lossy.cost_from_nnz(sparse_msg.nnz(), dim, MessageClass::Beta).is_some());
    }
}
