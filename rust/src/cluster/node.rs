//! Stateful worker endpoint of the node protocol. A [`WorkerNode`] owns
//! everything one machine of the paper's cluster owns:
//!
//! * its feature shard and subproblem engine,
//! * **its β shard** — updated locally with `α·Δβ_local` on every
//!   [`NodeMessage::Apply`], so no `beta_local` gather ever travels,
//! * **its margins copy** — updated with `α·Δm` from the same `Apply`,
//!   from which it derives the working statistics `(w, z)` locally each
//!   sweep (paper Alg 4: every machine computes the stats from its own
//!   margin vector).
//!
//! The node is transport-agnostic: [`WorkerNode::handle`] maps one request
//! to at most one reply, and [`WorkerNode::serve`] runs the
//! request/reply loop over any [`Transport`] — the in-process `WorkerPool`
//! drives `handle` directly from its worker threads, the `dglmnet worker`
//! CLI subcommand runs `serve` over a [`SocketTransport`] in a separate
//! process.
//!
//! **Bit-exactness contract.** The leader applies the merged update as
//! `β[j] += α·Δβ[j]` / `margins[i] += α·Δm[i]` in f32. The node applies
//! the identical operations to its shard: the feature partition is
//! disjoint, so the merged Δβ restricted to this node's columns is
//! bit-equal to the node's own sweep output (an f32 survives the f64 tree
//! accumulator round trip exactly), and the merged Δm arrives verbatim in
//! the `Apply`. Leader-held and worker-held state therefore stay
//! bit-identical, which the checkpoint pull verifies with a full β compare
//! and a margins checksum.
//!
//! [`SocketTransport`]: crate::cluster::transport::SocketTransport

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::protocol::{crc_f32, crc_u32, NodeMessage};
use crate::cluster::transport::Transport;
use crate::config::TrainConfig;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::data::store::ShardStore;
use crate::engine::{build_engine, SubproblemEngine};
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;

/// One worker machine as a protocol endpoint.
pub struct WorkerNode {
    machine: usize,
    n: usize,
    p: usize,
    global_cols: Vec<u32>,
    engine: Box<dyn SubproblemEngine>,
    /// Shared labels (read-only): one allocation for the whole in-process
    /// pool, an owned copy per remote worker process.
    y: Arc<Vec<f32>>,
    /// Worker-held β shard (shard-local column order).
    beta_local: Vec<f32>,
    /// Worker-held margins copy, kept bit-identical to the leader's.
    margins: Vec<f32>,
    /// Δβ of the most recent sweep — what an `Apply` without an explicit
    /// merged Δβ scales into `beta_local`.
    last_delta: SparseVec,
    /// GLM family the node derives its working statistics under — must
    /// match the leader's (validated at handshake).
    family: FamilyKind,
    /// Working-statistics scratch (cleared and refilled each sweep).
    w: Vec<f32>,
    z: Vec<f32>,
    /// λ_max target scratch (families whose targets aren't `y` itself).
    lm_scratch: Vec<f32>,
}

impl WorkerNode {
    /// Build the node for one shard: the engine is constructed in the
    /// calling thread (PJRT clients are thread-bound), state starts at
    /// β = 0 / margins = 0 — the same cold state the leader starts from.
    pub fn from_shard(
        cfg: &TrainConfig,
        shard: FeatureShard,
        y: Arc<Vec<f32>>,
        p: usize,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        let n = y.len();
        let machine = shard.machine;
        let global_cols = shard.global_cols.clone();
        let local_p = global_cols.len();
        let engine = build_engine(cfg, shard, n, artifacts_dir)?;
        Ok(Self {
            machine,
            n,
            p,
            global_cols,
            engine,
            y,
            beta_local: vec![0f32; local_p],
            margins: vec![0f32; n],
            last_delta: SparseVec::new(local_p),
            family: cfg.family,
            w: Vec::new(),
            z: Vec::new(),
            lm_scratch: Vec::new(),
        })
    }

    /// Self-load this machine's shard (and the labels) from an on-disk
    /// [`ShardStore`] — the out-of-core construction path: the worker reads
    /// *only its own* shard file (checksum-verified against the manifest),
    /// and no shard payload ever travels through the leader. Used by the
    /// in-process store pool, the `dglmnet worker --store` subcommand, and
    /// the store-driven socket tests.
    pub fn from_store(
        cfg: &TrainConfig,
        store: &ShardStore,
        machine: usize,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        let shard = store.load_shard(machine)?;
        let y = Arc::new(store.load_y()?);
        Self::from_shard(cfg, shard, y, store.p(), artifacts_dir)
    }

    pub fn machine(&self) -> usize {
        self.machine
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// The handshake announcement the leader validates on accept.
    pub fn join_message(&self) -> NodeMessage {
        NodeMessage::Join {
            machine: self.machine as u32,
            n: self.n as u32,
            p: self.p as u32,
            local_features: self.global_cols.len() as u32,
            cols_checksum: crc_u32(&self.global_cols),
            engine: self.engine.name().to_string(),
            family: self.family.name().to_string(),
        }
    }

    /// Process one request; `Ok(None)` means shutdown (the serve loop
    /// exits cleanly).
    pub fn handle(&mut self, msg: NodeMessage) -> Result<Option<NodeMessage>> {
        match msg {
            NodeMessage::Sweep { lam, nu, l2, mut recycle } => {
                // stats from the worker-held margins — no leader broadcast
                let t0 = Instant::now();
                self.family.family().working_stats_into(
                    &self.margins,
                    &self.y,
                    &mut self.w,
                    &mut self.z,
                );
                let stats_secs = t0.elapsed().as_secs_f64();
                self.engine
                    .sweep(&self.w, &self.z, &self.beta_local, lam, nu, l2, &mut recycle)?;
                recycle.compute_secs += stats_secs;
                // remember Δβ_local for the upcoming Apply
                self.last_delta.clear(recycle.delta_local.dim);
                self.last_delta
                    .indices
                    .extend_from_slice(&recycle.delta_local.indices);
                self.last_delta
                    .values
                    .extend_from_slice(&recycle.delta_local.values);
                Ok(Some(NodeMessage::Swept { result: recycle }))
            }
            NodeMessage::Apply { alpha, dmargins, delta } => {
                if dmargins.dim != self.n {
                    return Err(DlrError::Solver(format!(
                        "apply carries Δm of dim {} but n = {}",
                        dmargins.dim, self.n
                    )));
                }
                match delta {
                    // lossless wire: this node's own Δβ is bit-equal to the
                    // merged Δβ on its (disjoint) coordinates
                    None => {
                        for (j, v) in self.last_delta.iter() {
                            self.beta_local[j as usize] += alpha * v;
                        }
                    }
                    // lossy β wire (`wire_f16_beta`): apply exactly the
                    // merged (quantized) global Δβ the leader applied,
                    // restricted to this node's columns (two-pointer walk
                    // over the sorted global ids)
                    Some(delta) => {
                        let mut l = 0usize;
                        for (g, v) in delta.iter() {
                            while l < self.global_cols.len() && self.global_cols[l] < g {
                                l += 1;
                            }
                            if l < self.global_cols.len() && self.global_cols[l] == g {
                                self.beta_local[l] += alpha * v;
                                l += 1;
                            }
                        }
                    }
                }
                dmargins.add_scaled_into(&mut self.margins, alpha);
                Ok(Some(NodeMessage::Ack))
            }
            NodeMessage::SetState { beta_local, margins } => {
                if beta_local.len() != self.beta_local.len() || margins.len() != self.n {
                    return Err(DlrError::Solver(format!(
                        "set-state shapes ({}, {}) do not match the shard ({}, {})",
                        beta_local.len(),
                        margins.len(),
                        self.beta_local.len(),
                        self.n
                    )));
                }
                self.beta_local.copy_from_slice(&beta_local);
                self.margins.copy_from_slice(&margins);
                self.last_delta.clear(self.beta_local.len());
                Ok(Some(NodeMessage::Ack))
            }
            NodeMessage::GetState => Ok(Some(NodeMessage::State {
                beta_local: self.beta_local.clone(),
                margins_crc: crc_f32(&self.margins),
            })),
            NodeMessage::LambdaMax => {
                let fam = self.family.family();
                let targets = fam.lambda_max_targets(&self.y, &mut self.lm_scratch);
                Ok(Some(NodeMessage::LambdaMaxed {
                    value: self.engine.lambda_max_local(targets, fam.lambda_max_scale())?,
                }))
            }
            NodeMessage::Margins { beta_local } => {
                if beta_local.len() != self.beta_local.len() {
                    return Err(DlrError::Solver(format!(
                        "margins request carries {} coefficients but this shard owns \
                         {} features",
                        beta_local.len(),
                        self.beta_local.len()
                    )));
                }
                let mut part = SparseVec::new(self.n);
                self.engine.margins_into(&beta_local, &mut part)?;
                Ok(Some(NodeMessage::MarginsPart { part }))
            }
            // liveness probe from the supervisor — answer and carry on
            NodeMessage::Ping => Ok(Some(NodeMessage::Pong)),
            NodeMessage::Shutdown => Ok(None),
            other => Err(DlrError::Solver(format!(
                "worker {} received unexpected {}",
                self.machine,
                other.name()
            ))),
        }
    }

    /// Run the node over a transport: announce, await admission, then
    /// request/reply until `Shutdown` (or a transport/engine failure,
    /// which is reported to the leader as an `Abort` before returning).
    pub fn serve(&mut self, transport: &mut dyn Transport) -> Result<()> {
        transport.send(self.join_message())?;
        match transport.recv()? {
            NodeMessage::Welcome { family, .. } => {
                // defense in depth: the leader validates the Join's family
                // and only welcomes a match, but a worker must never sweep
                // under the wrong loss even against a buggy leader
                if family != self.family.name() {
                    return Err(DlrError::Solver(format!(
                        "leader runs family '{family}' but worker {} was started \
                         with '{}' (pass the matching --family to the worker)",
                        self.machine,
                        self.family.name()
                    )));
                }
            }
            NodeMessage::Abort { message } => {
                return Err(DlrError::Solver(format!(
                    "leader rejected worker {}: {message}",
                    self.machine
                )))
            }
            other => {
                return Err(DlrError::Solver(format!(
                    "expected welcome, got {}",
                    other.name()
                )))
            }
        }
        loop {
            let msg = transport.recv()?;
            match self.handle(msg) {
                Ok(Some(reply)) => transport.send(reply)?,
                Ok(None) => return Ok(()),
                Err(e) => {
                    if let Err(send_err) =
                        transport.send(NodeMessage::Abort { message: e.to_string() })
                    {
                        crate::cluster::protocol::log_lost_abort(
                            self.machine,
                            "serve",
                            &send_err,
                        );
                    }
                    return Err(e);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::config::EngineKind;
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;

    fn node_for(machine: usize, m: usize) -> (WorkerNode, crate::data::Dataset) {
        let ds = synth::dna_like(120, 24, 4, 51);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 24, m, None);
        let shard = shard_in_memory(&ds.x, &part).remove(machine);
        let cfg = TrainConfig::builder().machines(m).engine(EngineKind::Native).build();
        let node =
            WorkerNode::from_shard(&cfg, shard, Arc::new(ds.y.clone()), 24, "artifacts".as_ref())
                .unwrap();
        (node, ds)
    }

    #[test]
    fn sweep_apply_keeps_shard_state_consistent() {
        let (mut node, _ds) = node_for(0, 2);
        let reply = node
            .handle(NodeMessage::Sweep {
                lam: 0.05,
                nu: 1e-6,
                l2: 0.0,
                recycle: Default::default(),
            })
            .unwrap()
            .unwrap();
        let result = match reply {
            NodeMessage::Swept { result } => result,
            other => panic!("expected swept, got {}", other.name()),
        };
        assert!(!result.delta_local.is_empty(), "λ small enough to move");
        // apply the node's own Δ at α = 0.5 (merged == own for one machine
        // coordinates)
        let dm = Arc::new(result.dmargins.clone());
        let ack = node
            .handle(NodeMessage::Apply { alpha: 0.5, dmargins: Arc::clone(&dm), delta: None })
            .unwrap()
            .unwrap();
        assert_eq!(ack.name(), "ack");
        // the shard state moved exactly α·Δ
        let state = node.handle(NodeMessage::GetState).unwrap().unwrap();
        match state {
            NodeMessage::State { beta_local, margins_crc } => {
                let mut want = vec![0f32; beta_local.len()];
                result.delta_local.add_scaled_into(&mut want, 0.5);
                for (a, b) in beta_local.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                let mut margins = vec![0f32; 120];
                dm.add_scaled_into(&mut margins, 0.5);
                assert_eq!(margins_crc, crc_f32(&margins));
            }
            other => panic!("expected state, got {}", other.name()),
        }
    }

    #[test]
    fn explicit_merged_delta_applies_only_owned_columns() {
        let (mut node, _ds) = node_for(1, 3); // owns global cols 1, 4, 7, ...
        // run one sweep so last_delta is non-empty — the explicit path must
        // ignore it and use the provided merged Δβ instead
        node.handle(NodeMessage::Sweep {
            lam: 0.5,
            nu: 1e-6,
            l2: 0.0,
            recycle: Default::default(),
        })
        .unwrap();
        let mut merged = SparseVec::new(24);
        merged.push(0, 10.0); // not owned
        merged.push(1, 2.0); // owned (local 0)
        merged.push(7, -4.0); // owned (local 2)
        merged.push(9, 5.0); // not owned
        let before = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, .. } => beta_local,
            _ => unreachable!(),
        };
        node.handle(NodeMessage::Apply {
            alpha: 0.5,
            dmargins: Arc::new(SparseVec::new(120)),
            delta: Some(Arc::new(merged)),
        })
        .unwrap();
        let after = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, .. } => beta_local,
            _ => unreachable!(),
        };
        assert_eq!(after[0], before[0] + 1.0, "global col 1 is local 0");
        assert_eq!(after[2], before[2] - 2.0, "global col 7 is local 2");
        for l in [1usize, 3, 4, 5, 6, 7] {
            if l < after.len() && l != 0 && l != 2 {
                assert_eq!(after[l].to_bits(), before[l].to_bits(), "local {l}");
            }
        }
    }

    #[test]
    fn set_state_validates_shapes_and_resets_last_delta() {
        let (mut node, _ds) = node_for(0, 2);
        let local_p = node.beta_local.len();
        // wrong shapes error
        assert!(node
            .handle(NodeMessage::SetState {
                beta_local: vec![0.0; local_p + 1],
                margins: Arc::new(vec![0.0; 120]),
            })
            .is_err());
        // correct shapes install bit-for-bit
        let beta: Vec<f32> = (0..local_p).map(|i| i as f32 * 0.25 - 1.0).collect();
        let margins: Vec<f32> = (0..120).map(|i| (i as f32).sin()).collect();
        node.handle(NodeMessage::SetState {
            beta_local: beta.clone(),
            margins: Arc::new(margins.clone()),
        })
        .unwrap();
        match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => {
                for (a, b) in beta_local.iter().zip(&beta) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(margins_crc, crc_f32(&margins));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn unexpected_messages_error() {
        let (mut node, _ds) = node_for(0, 2);
        assert!(node
            .handle(NodeMessage::Welcome { family: "logistic".into(), alpha: 1.0 })
            .is_err());
        assert!(node.handle(NodeMessage::Ack).is_err());
        assert!(matches!(node.handle(NodeMessage::Shutdown), Ok(None)));
    }

    #[test]
    fn ping_answers_pong_without_touching_state() {
        let (mut node, _ds) = node_for(0, 2);
        let before = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => (beta_local, margins_crc),
            _ => unreachable!(),
        };
        let reply = node.handle(NodeMessage::Ping).unwrap().unwrap();
        assert_eq!(reply.name(), "pong");
        match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => {
                assert_eq!(beta_local, before.0);
                assert_eq!(margins_crc, before.1);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn join_message_carries_shard_identity() {
        let (node, _ds) = node_for(1, 2);
        match node.join_message() {
            NodeMessage::Join {
                machine,
                n,
                p,
                local_features,
                cols_checksum,
                engine,
                family,
            } => {
                assert_eq!(machine, 1);
                assert_eq!(n, 120);
                assert_eq!(p, 24);
                assert_eq!(local_features, 12);
                let cols: Vec<u32> = (0..24u32).filter(|c| c % 2 == 1).collect();
                assert_eq!(cols_checksum, crc_u32(&cols));
                assert_eq!(engine, "native");
                assert_eq!(family, "logistic");
            }
            other => panic!("expected join, got {}", other.name()),
        }
    }
}
