//! Stateful worker endpoint of the node protocol. A [`WorkerNode`] owns
//! everything one machine of the paper's cluster owns:
//!
//! * its feature shard and subproblem engine,
//! * **its β shard** — updated locally with `α·Δβ_local` on every
//!   [`NodeMessage::Apply`], so no `beta_local` gather ever travels,
//! * **its margins copy** — updated with `α·Δm` from the same `Apply`,
//!   from which it derives the working statistics `(w, z)` locally each
//!   sweep (paper Alg 4: every machine computes the stats from its own
//!   margin vector).
//!
//! The node is transport-agnostic: [`WorkerNode::handle`] maps one request
//! to at most one reply, and [`WorkerNode::serve`] runs the
//! request/reply loop over any [`Transport`] — the in-process `WorkerPool`
//! drives `handle` directly from its worker threads, the `dglmnet worker`
//! CLI subcommand runs `serve` over a [`SocketTransport`] in a separate
//! process.
//!
//! **Tree topology.** When the `Welcome` carries a
//! [`Topology`](crate::cluster::protocol::Topology), the node switches to
//! peer-to-peer serving: it builds direct worker↔worker links from a
//! [`PeerTable`], receives `Sweep`/`Apply` from its bracket parent (machine
//! 0: from the leader), relays them verbatim to its bracket children, folds
//! the children's [`TreeSwept`] payloads into its own f64 accumulators in
//! bracket order — the exact merges the leader-staged engine would run —
//! and ships one merged message to its parent. The leader control link
//! stays responsive throughout (pings are answered mid-collective), so
//! supervision works unchanged.
//!
//! **Bit-exactness contract.** The leader applies the merged update as
//! `β[j] += α·Δβ[j]` / `margins[i] += α·Δm[i]` in f32. The node applies
//! the identical operations to its shard: the feature partition is
//! disjoint, so the merged Δβ restricted to this node's columns is
//! bit-equal to the node's own sweep output (an f32 survives the f64 tree
//! accumulator round trip exactly), and the merged Δm arrives verbatim in
//! the `Apply`. Leader-held and worker-held state therefore stay
//! bit-identical, which the checkpoint pull verifies with a full β compare
//! and a margins checksum.
//!
//! [`SocketTransport`]: crate::cluster::transport::SocketTransport

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::allreduce::merge_sorted_into;
use crate::cluster::protocol::{
    crc_f32, crc_u32, EdgeStat, NodeMessage, OriginStat, Topology, TreePayload, TreeSwept,
};
use crate::cluster::transport::{PeerTable, SocketTransport, Transport};
use crate::config::TrainConfig;
use crate::data::shuffle::FeatureShard;
use crate::data::sparse::SparseVec;
use crate::data::store::ShardStore;
use crate::engine::{build_engine, SubproblemEngine, SweepResult};
use crate::error::{DlrError, Result};
use crate::family::FamilyKind;

/// Poll quantum for peer links while a collective is in flight.
const PEER_POLL: Duration = Duration::from_millis(25);
/// Poll quantum for the leader control link while awaiting a peer — short,
/// so peer traffic latency stays dominated by `PEER_POLL`.
const CTL_POLL: Duration = Duration::from_millis(1);
/// Poll quantum of the idle tree serve loop (leader link, then parent).
const SERVE_POLL: Duration = Duration::from_millis(25);

/// How a tree collective concluded on this node.
enum TreeFlow {
    /// Finished; the merged result / ack went up the arrival link.
    Done,
    /// A leader-link message (topology refresh, rollback, shutdown)
    /// interrupted the collective — the serve loop must process it as if
    /// freshly received, and owes the collective nothing.
    Deferred(NodeMessage),
}

/// What a peer-link wait produced.
enum PeerRecv {
    Msg(NodeMessage),
    Deferred(NodeMessage),
}

/// Wait for one message on a peer link while keeping the leader control
/// link responsive: pings are answered inline, any other leader message
/// interrupts the wait and is handed back for the serve loop.
fn recv_from_peer(
    peer_machine: u32,
    kind: &str,
    peer: &mut SocketTransport,
    leader: &mut dyn Transport,
    timeout: Option<Duration>,
) -> Result<PeerRecv> {
    let start = Instant::now();
    loop {
        if let Some(msg) = peer.recv_poll(PEER_POLL)? {
            return Ok(PeerRecv::Msg(msg));
        }
        match leader.recv_poll(CTL_POLL)? {
            Some(NodeMessage::Ping) => leader.send(NodeMessage::Pong)?,
            Some(other) => return Ok(PeerRecv::Deferred(other)),
            None => {}
        }
        if let Some(t) = timeout {
            if start.elapsed() > t {
                return Err(DlrError::Solver(format!(
                    "timed out waiting for tree {kind} {peer_machine}"
                )));
            }
        }
    }
}

/// One worker machine as a protocol endpoint.
pub struct WorkerNode {
    machine: usize,
    n: usize,
    p: usize,
    global_cols: Vec<u32>,
    engine: Box<dyn SubproblemEngine>,
    /// Shared labels (read-only): one allocation for the whole in-process
    /// pool, an owned copy per remote worker process.
    y: Arc<Vec<f32>>,
    /// Worker-held β shard (shard-local column order).
    beta_local: Vec<f32>,
    /// Worker-held margins copy, kept bit-identical to the leader's.
    margins: Vec<f32>,
    /// Δβ of the most recent sweep — what an `Apply` without an explicit
    /// merged Δβ scales into `beta_local`.
    last_delta: SparseVec,
    /// GLM family the node derives its working statistics under — must
    /// match the leader's (validated at handshake).
    family: FamilyKind,
    /// Working-statistics scratch (cleared and refilled each sweep).
    w: Vec<f32>,
    z: Vec<f32>,
    /// λ_max target scratch (families whose targets aren't `y` itself).
    lm_scratch: Vec<f32>,
}

impl WorkerNode {
    /// Build the node for one shard: the engine is constructed in the
    /// calling thread (PJRT clients are thread-bound), state starts at
    /// β = 0 / margins = 0 — the same cold state the leader starts from.
    pub fn from_shard(
        cfg: &TrainConfig,
        shard: FeatureShard,
        y: Arc<Vec<f32>>,
        p: usize,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        let n = y.len();
        let machine = shard.machine;
        let global_cols = shard.global_cols.clone();
        let local_p = global_cols.len();
        let engine = build_engine(cfg, shard, n, artifacts_dir)?;
        Ok(Self {
            machine,
            n,
            p,
            global_cols,
            engine,
            y,
            beta_local: vec![0f32; local_p],
            margins: vec![0f32; n],
            last_delta: SparseVec::new(local_p),
            family: cfg.family,
            w: Vec::new(),
            z: Vec::new(),
            lm_scratch: Vec::new(),
        })
    }

    /// Self-load this machine's shard (and the labels) from an on-disk
    /// [`ShardStore`] — the out-of-core construction path: the worker reads
    /// *only its own* shard file (checksum-verified against the manifest),
    /// and no shard payload ever travels through the leader. Used by the
    /// in-process store pool, the `dglmnet worker --store` subcommand, and
    /// the store-driven socket tests.
    pub fn from_store(
        cfg: &TrainConfig,
        store: &ShardStore,
        machine: usize,
        artifacts_dir: &std::path::Path,
    ) -> Result<Self> {
        let shard = store.load_shard(machine)?;
        let y = Arc::new(store.load_y()?);
        Self::from_shard(cfg, shard, y, store.p(), artifacts_dir)
    }

    pub fn machine(&self) -> usize {
        self.machine
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// The handshake announcement the leader validates on accept.
    /// `listen_addr` is the worker's peer-listener address for tree runs
    /// (empty when the worker binds none).
    pub fn join_message(&self, listen_addr: &str) -> NodeMessage {
        NodeMessage::Join {
            machine: self.machine as u32,
            n: self.n as u32,
            p: self.p as u32,
            local_features: self.global_cols.len() as u32,
            cols_checksum: crc_u32(&self.global_cols),
            engine: self.engine.name().to_string(),
            family: self.family.name().to_string(),
            listen_addr: listen_addr.to_string(),
        }
    }

    /// One CD sweep over the worker-held shard state: derive `(w, z)` from
    /// the worker's margins, sweep the engine, remember `Δβ_local` for the
    /// upcoming `Apply`. Shared by the star reply path and the tree
    /// collective.
    fn run_sweep(
        &mut self,
        lam: f32,
        nu: f32,
        l2: f32,
        mut recycle: SweepResult,
    ) -> Result<SweepResult> {
        // stats from the worker-held margins — no leader broadcast
        let t0 = Instant::now();
        self.family.family().working_stats_into(
            &self.margins,
            &self.y,
            &mut self.w,
            &mut self.z,
        );
        let stats_secs = t0.elapsed().as_secs_f64();
        self.engine
            .sweep(&self.w, &self.z, &self.beta_local, lam, nu, l2, &mut recycle)?;
        recycle.compute_secs += stats_secs;
        // remember Δβ_local for the upcoming Apply
        self.last_delta.clear(recycle.delta_local.dim);
        self.last_delta
            .indices
            .extend_from_slice(&recycle.delta_local.indices);
        self.last_delta
            .values
            .extend_from_slice(&recycle.delta_local.values);
        Ok(recycle)
    }

    /// Process one request; `Ok(None)` means shutdown (the serve loop
    /// exits cleanly).
    pub fn handle(&mut self, msg: NodeMessage) -> Result<Option<NodeMessage>> {
        match msg {
            NodeMessage::Sweep { lam, nu, l2, recycle } => {
                let result = self.run_sweep(lam, nu, l2, recycle)?;
                Ok(Some(NodeMessage::Swept { result }))
            }
            NodeMessage::Apply { alpha, dmargins, delta } => {
                if dmargins.dim != self.n {
                    return Err(DlrError::Solver(format!(
                        "apply carries Δm of dim {} but n = {}",
                        dmargins.dim, self.n
                    )));
                }
                match delta {
                    // lossless wire: this node's own Δβ is bit-equal to the
                    // merged Δβ on its (disjoint) coordinates
                    None => {
                        for (j, v) in self.last_delta.iter() {
                            self.beta_local[j as usize] += alpha * v;
                        }
                    }
                    // lossy β wire (`wire_f16_beta`): apply exactly the
                    // merged (quantized) global Δβ the leader applied,
                    // restricted to this node's columns (two-pointer walk
                    // over the sorted global ids)
                    Some(delta) => {
                        let mut l = 0usize;
                        for (g, v) in delta.iter() {
                            while l < self.global_cols.len() && self.global_cols[l] < g {
                                l += 1;
                            }
                            if l < self.global_cols.len() && self.global_cols[l] == g {
                                self.beta_local[l] += alpha * v;
                                l += 1;
                            }
                        }
                    }
                }
                dmargins.add_scaled_into(&mut self.margins, alpha);
                Ok(Some(NodeMessage::Ack))
            }
            NodeMessage::SetState { beta_local, margins } => {
                if beta_local.len() != self.beta_local.len() || margins.len() != self.n {
                    return Err(DlrError::Solver(format!(
                        "set-state shapes ({}, {}) do not match the shard ({}, {})",
                        beta_local.len(),
                        margins.len(),
                        self.beta_local.len(),
                        self.n
                    )));
                }
                self.beta_local.copy_from_slice(&beta_local);
                self.margins.copy_from_slice(&margins);
                self.last_delta.clear(self.beta_local.len());
                Ok(Some(NodeMessage::Ack))
            }
            NodeMessage::GetState => Ok(Some(NodeMessage::State {
                beta_local: self.beta_local.clone(),
                margins_crc: crc_f32(&self.margins),
            })),
            NodeMessage::LambdaMax => {
                let fam = self.family.family();
                let targets = fam.lambda_max_targets(&self.y, &mut self.lm_scratch);
                Ok(Some(NodeMessage::LambdaMaxed {
                    value: self.engine.lambda_max_local(targets, fam.lambda_max_scale())?,
                }))
            }
            NodeMessage::Margins { beta_local } => {
                if beta_local.len() != self.beta_local.len() {
                    return Err(DlrError::Solver(format!(
                        "margins request carries {} coefficients but this shard owns \
                         {} features",
                        beta_local.len(),
                        self.beta_local.len()
                    )));
                }
                let mut part = SparseVec::new(self.n);
                self.engine.margins_into(&beta_local, &mut part)?;
                Ok(Some(NodeMessage::MarginsPart { part }))
            }
            // liveness probe from the supervisor — answer and carry on
            NodeMessage::Ping => Ok(Some(NodeMessage::Pong)),
            NodeMessage::Shutdown => Ok(None),
            other => Err(DlrError::Solver(format!(
                "worker {} received unexpected {}",
                self.machine,
                other.name()
            ))),
        }
    }

    /// Run the node over a transport: announce, await admission, then
    /// request/reply until `Shutdown` (or a transport/engine failure,
    /// which is reported to the leader as an `Abort` before returning).
    ///
    /// `peers` is the worker's peer-link table for tree-topology runs;
    /// `None` serves star-only. When the leader's `Welcome` (or a later
    /// [`NodeMessage::Topology`]) carries a topology, the node builds its
    /// peer links and switches to the tree serve loop.
    pub fn serve(
        &mut self,
        transport: &mut dyn Transport,
        mut peers: Option<&mut PeerTable>,
    ) -> Result<()> {
        let listen_addr =
            peers.as_ref().map(|p| p.advertised_addr().to_string()).unwrap_or_default();
        transport.send(self.join_message(&listen_addr))?;
        let mut topo: Option<Topology> = None;
        match transport.recv()? {
            NodeMessage::Welcome { family, topology, .. } => {
                // defense in depth: the leader validates the Join's family
                // and only welcomes a match, but a worker must never sweep
                // under the wrong loss even against a buggy leader
                if family != self.family.name() {
                    return Err(DlrError::Solver(format!(
                        "leader runs family '{family}' but worker {} was started \
                         with '{}' (pass the matching --family to the worker)",
                        self.machine,
                        self.family.name()
                    )));
                }
                if let Some(t) = topology {
                    let table = peers.as_deref_mut().ok_or_else(|| {
                        DlrError::Solver(format!(
                            "leader runs the tree topology but worker {} has no peer \
                             listener (start it with --topology tree)",
                            self.machine
                        ))
                    })?;
                    table.rebuild(&t, self.machine as u32, crc_u32(&self.global_cols))?;
                    topo = Some(t);
                }
            }
            NodeMessage::Abort { message } => {
                return Err(DlrError::Solver(format!(
                    "leader rejected worker {}: {message}",
                    self.machine
                )))
            }
            other => {
                return Err(DlrError::Solver(format!(
                    "expected welcome, got {}",
                    other.name()
                )))
            }
        }
        // with a peer table the node always runs the tree loop: a welcome
        // without a topology (a re-admitted replacement, or a tree worker
        // joining a star leader) idles at epoch 0 — answering everything
        // star-style — until a `Topology` message installs the tree
        if let Some(peers) = peers {
            return self.serve_tree(transport, peers, topo.unwrap_or_default());
        }
        match topo {
            Some(_) => unreachable!("topology admission requires a peer table"),
            None => loop {
                let msg = transport.recv()?;
                match self.handle(msg) {
                    Ok(Some(reply)) => transport.send(reply)?,
                    Ok(None) => return Ok(()),
                    Err(e) => {
                        if let Err(send_err) =
                            transport.send(NodeMessage::Abort { message: e.to_string() })
                        {
                            crate::cluster::protocol::log_lost_abort(
                                self.machine,
                                "serve",
                                &send_err,
                            );
                        }
                        return Err(e);
                    }
                }
            },
        }
    }

    /// The tree serve loop: poll the leader control link, then the bracket
    /// parent link. Data traffic (`Sweep`/`Apply`) arrives from the parent
    /// (machine 0: from the leader) and is answered up the same link;
    /// everything else is leader control.
    ///
    /// Collective failures (a dead or wedged peer) are **not** fatal: the
    /// node reports an `Abort` up its arrival link and keeps serving — the
    /// supervisor rolls the run back and re-issues a fresh-epoch topology,
    /// which tears down every peer link (discarding any stale in-flight
    /// payloads with them) and rebuilds the tree.
    fn serve_tree(
        &mut self,
        transport: &mut dyn Transport,
        peers: &mut PeerTable,
        mut topo: Topology,
    ) -> Result<()> {
        let mut pending: Option<NodeMessage> = None;
        loop {
            // 1. leader link: a message deferred out of a collective, or
            //    freshly polled control traffic
            let lmsg = match pending.take() {
                Some(m) => Some(m),
                None => transport.recv_poll(SERVE_POLL)?,
            };
            if let Some(msg) = lmsg {
                match msg {
                    NodeMessage::Topology(t) => {
                        peers.rebuild(&t, self.machine as u32, crc_u32(&self.global_cols))?;
                        topo = t;
                    }
                    // epoch 0 = no topology installed yet (a freshly
                    // re-admitted replacement): data traffic falls through
                    // to `handle` and is answered star-style until the
                    // supervisor re-issues the tree
                    NodeMessage::Sweep { lam, nu, l2, .. } if topo.epoch > 0 => {
                        match self.tree_sweep(lam, nu, l2, &topo, peers, transport) {
                            Ok(TreeFlow::Done) => {}
                            Ok(TreeFlow::Deferred(m)) => pending = Some(m),
                            Err(e) => {
                                // leader is the arrival link — if even the
                                // abort can't travel, the leader is gone
                                if transport
                                    .send(NodeMessage::Abort { message: e.to_string() })
                                    .is_err()
                                {
                                    return Err(e);
                                }
                            }
                        }
                    }
                    NodeMessage::Apply { alpha, dmargins, delta } if topo.epoch > 0 => {
                        match self.tree_apply(alpha, dmargins, delta, &topo, peers, transport)
                        {
                            Ok(TreeFlow::Done) => transport.send(NodeMessage::Ack)?,
                            Ok(TreeFlow::Deferred(m)) => pending = Some(m),
                            Err(e) => {
                                if transport
                                    .send(NodeMessage::Abort { message: e.to_string() })
                                    .is_err()
                                {
                                    return Err(e);
                                }
                            }
                        }
                    }
                    other => match self.handle(other) {
                        Ok(Some(reply)) => transport.send(reply)?,
                        Ok(None) => return Ok(()),
                        Err(e) => {
                            if let Err(send_err) =
                                transport.send(NodeMessage::Abort { message: e.to_string() })
                            {
                                crate::cluster::protocol::log_lost_abort(
                                    self.machine,
                                    "serve-tree",
                                    &send_err,
                                );
                            }
                            return Err(e);
                        }
                    },
                }
                continue;
            }
            // 2. parent link: tree data traffic relayed down the bracket
            let pmsg = match peers.parent_mut() {
                Some(link) => match link.recv_poll(SERVE_POLL) {
                    Ok(m) => m,
                    Err(_) => {
                        // parent hung up — drop every peer link and keep
                        // serving the leader, which will re-issue a topology
                        peers.drop_links();
                        None
                    }
                },
                None => None,
            };
            if let Some(msg) = pmsg {
                let flow = match msg {
                    NodeMessage::Sweep { lam, nu, l2, .. } => {
                        self.tree_sweep(lam, nu, l2, &topo, peers, transport)
                    }
                    NodeMessage::Apply { alpha, dmargins, delta } => self
                        .tree_apply(alpha, dmargins, delta, &topo, peers, transport)
                        .map(|flow| {
                            if let TreeFlow::Done = flow {
                                if let Some(link) = peers.parent_mut() {
                                    if link.send(NodeMessage::Ack).is_err() {
                                        peers.drop_links();
                                    }
                                }
                            }
                            flow
                        }),
                    other => Err(DlrError::Solver(format!(
                        "worker {} received unexpected {} on its tree parent link",
                        self.machine,
                        other.name()
                    ))),
                };
                match flow {
                    Ok(TreeFlow::Done) => {}
                    Ok(TreeFlow::Deferred(m)) => pending = Some(m),
                    Err(e) => {
                        // report up the arrival (parent) link and survive —
                        // the supervisor heals the tree
                        if let Some(link) = peers.parent_mut() {
                            if link
                                .send(NodeMessage::Abort { message: e.to_string() })
                                .is_err()
                            {
                                peers.drop_links();
                            }
                        }
                    }
                }
            }
        }
    }

    /// The tree sweep collective on this node: relay the sweep to every
    /// bracket child, run the local sweep, remap `Δβ_local` to global ids,
    /// fold the children's merged payloads into f64 accumulators in bracket
    /// order, and ship one [`TreeSwept`] up the arrival link — to the
    /// bracket parent, or (machine 0) the f32-rounded root result to the
    /// leader, rounded exactly where the leader-staged engine rounds.
    fn tree_sweep(
        &mut self,
        lam: f32,
        nu: f32,
        l2: f32,
        topo: &Topology,
        peers: &mut PeerTable,
        leader: &mut dyn Transport,
    ) -> Result<TreeFlow> {
        let timeout = if topo.peer_timeout_secs > 0.0 {
            Some(Duration::from_secs_f64(topo.peer_timeout_secs))
        } else {
            None
        };
        // fan out first so the subtree computes while this node sweeps
        for (_, link) in peers.children_mut().iter_mut() {
            link.send(NodeMessage::Sweep { lam, nu, l2, recycle: SweepResult::default() })?;
        }
        let result = self.run_sweep(lam, nu, l2, SweepResult::default())?;
        // own contribution, shard-local → global ids (global_cols ascends,
        // so the remapped indices stay sorted), f32 → f64 exactly as the
        // staged engine lifts contributions into its tree accumulators
        let mut db_idx: Vec<u32> = result
            .delta_local
            .indices
            .iter()
            .map(|&j| self.global_cols[j as usize])
            .collect();
        let mut db_val: Vec<f64> =
            result.delta_local.values.iter().map(|&v| v as f64).collect();
        let mut dm_idx: Vec<u32> = result.dmargins.indices.clone();
        let mut dm_val: Vec<f64> = result.dmargins.values.iter().map(|&v| v as f64).collect();
        let mut origins = vec![OriginStat {
            machine: self.machine as u32,
            compute_secs: result.compute_secs,
            db_nnz: db_idx.len() as u32,
            dm_nnz: dm_idx.len() as u32,
        }];
        let mut edges: Vec<EdgeStat> = Vec::new();
        let (mut mi, mut mv) = (Vec::new(), Vec::new());
        let nchild = peers.children_mut().len();
        for slot in 0..nchild {
            let (child_machine, received) = {
                let (cm, link) = &mut peers.children_mut()[slot];
                let cm = *cm;
                (cm, recv_from_peer(cm, "child", link, leader, timeout)?)
            };
            let swept = match received {
                PeerRecv::Deferred(m) => return Ok(TreeFlow::Deferred(m)),
                PeerRecv::Msg(NodeMessage::TreeSwept(swept)) => swept,
                PeerRecv::Msg(NodeMessage::Abort { message }) => {
                    return Err(DlrError::Solver(format!(
                        "tree child {child_machine} aborted: {message}"
                    )))
                }
                PeerRecv::Msg(other) => {
                    return Err(DlrError::Solver(format!(
                        "expected tree-swept from child {child_machine}, got {}",
                        other.name()
                    )))
                }
            };
            if swept.db.dim as usize != self.p || swept.dm.dim as usize != self.n {
                return Err(DlrError::Solver(format!(
                    "tree child {child_machine} sent payload dims ({}, {}) but the \
                     problem is ({}, {})",
                    swept.db.dim, swept.dm.dim, self.p, self.n
                )));
            }
            // this node's accumulator is the bracket's lower (surviving)
            // slot: it is the `a` side of the pairwise merge, the child the
            // `b` side — the a+b summation order of the staged engine
            merge_sorted_into(&db_idx, &db_val, &swept.db.indices, &swept.db.values, &mut mi, &mut mv);
            std::mem::swap(&mut db_idx, &mut mi);
            std::mem::swap(&mut db_val, &mut mv);
            merge_sorted_into(&dm_idx, &dm_val, &swept.dm.indices, &swept.dm.values, &mut mi, &mut mv);
            std::mem::swap(&mut dm_idx, &mut mi);
            std::mem::swap(&mut dm_val, &mut mv);
            origins.extend_from_slice(&swept.origins);
            edges.extend_from_slice(&swept.edges);
        }
        let mut swept = TreeSwept {
            db: TreePayload { dim: self.p as u32, indices: db_idx, values: db_val },
            dm: TreePayload { dim: self.n as u32, indices: dm_idx, values: dm_val },
            origins,
            edges,
        };
        match topo.parent.as_ref() {
            Some(parent) => {
                // charge metadata for the leader's ledger replay: the
                // accumulated nnz this edge actually carries
                swept.edges.push(EdgeStat {
                    into: parent.machine,
                    from: self.machine as u32,
                    db_nnz: swept.db.nnz() as u32,
                    dm_nnz: swept.dm.nnz() as u32,
                });
                let link = peers.parent_mut().ok_or_else(|| {
                    DlrError::Solver(format!(
                        "worker {} has no live link to tree parent {}",
                        self.machine, parent.machine
                    ))
                })?;
                link.send(NodeMessage::TreeSwept(swept))?;
            }
            None => {
                // bracket root: round both payloads to f32 — the exact
                // `v as f32` the staged engine applies when it reads the
                // root accumulator out as the merged result
                for v in swept.db.values.iter_mut() {
                    *v = (*v as f32) as f64;
                }
                for v in swept.dm.values.iter_mut() {
                    *v = (*v as f32) as f64;
                }
                leader.send(NodeMessage::TreeSwept(swept))?;
            }
        }
        Ok(TreeFlow::Done)
    }

    /// The tree apply collective: relay the `Apply` verbatim to every
    /// bracket child, apply locally, await the children's acks. The caller
    /// sends the single aggregated `Ack` up the arrival link.
    fn tree_apply(
        &mut self,
        alpha: f32,
        dmargins: Arc<SparseVec>,
        delta: Option<Arc<SparseVec>>,
        topo: &Topology,
        peers: &mut PeerTable,
        leader: &mut dyn Transport,
    ) -> Result<TreeFlow> {
        let timeout = if topo.peer_timeout_secs > 0.0 {
            Some(Duration::from_secs_f64(topo.peer_timeout_secs))
        } else {
            None
        };
        for (_, link) in peers.children_mut().iter_mut() {
            link.send(NodeMessage::Apply {
                alpha,
                dmargins: Arc::clone(&dmargins),
                delta: delta.clone(),
            })?;
        }
        let reply = self.handle(NodeMessage::Apply { alpha, dmargins, delta })?;
        debug_assert!(matches!(reply, Some(NodeMessage::Ack)));
        let nchild = peers.children_mut().len();
        for slot in 0..nchild {
            let (child_machine, received) = {
                let (cm, link) = &mut peers.children_mut()[slot];
                let cm = *cm;
                (cm, recv_from_peer(cm, "child", link, leader, timeout)?)
            };
            match received {
                PeerRecv::Deferred(m) => return Ok(TreeFlow::Deferred(m)),
                PeerRecv::Msg(NodeMessage::Ack) => {}
                PeerRecv::Msg(NodeMessage::Abort { message }) => {
                    return Err(DlrError::Solver(format!(
                        "tree child {child_machine} aborted the apply: {message}"
                    )))
                }
                PeerRecv::Msg(other) => {
                    return Err(DlrError::Solver(format!(
                        "expected ack from tree child {child_machine}, got {}",
                        other.name()
                    )))
                }
            }
        }
        Ok(TreeFlow::Done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::cluster::partition::{FeaturePartition, PartitionStrategy};
    use crate::config::EngineKind;
    use crate::data::shuffle::shard_in_memory;
    use crate::data::synth;

    fn node_for(machine: usize, m: usize) -> (WorkerNode, crate::data::Dataset) {
        let ds = synth::dna_like(120, 24, 4, 51);
        let part = FeaturePartition::build(PartitionStrategy::RoundRobin, 24, m, None);
        let shard = shard_in_memory(&ds.x, &part).remove(machine);
        let cfg = TrainConfig::builder().machines(m).engine(EngineKind::Native).build();
        let node =
            WorkerNode::from_shard(&cfg, shard, Arc::new(ds.y.clone()), 24, "artifacts".as_ref())
                .unwrap();
        (node, ds)
    }

    #[test]
    fn sweep_apply_keeps_shard_state_consistent() {
        let (mut node, _ds) = node_for(0, 2);
        let reply = node
            .handle(NodeMessage::Sweep {
                lam: 0.05,
                nu: 1e-6,
                l2: 0.0,
                recycle: Default::default(),
            })
            .unwrap()
            .unwrap();
        let result = match reply {
            NodeMessage::Swept { result } => result,
            other => panic!("expected swept, got {}", other.name()),
        };
        assert!(!result.delta_local.is_empty(), "λ small enough to move");
        // apply the node's own Δ at α = 0.5 (merged == own for one machine
        // coordinates)
        let dm = Arc::new(result.dmargins.clone());
        let ack = node
            .handle(NodeMessage::Apply { alpha: 0.5, dmargins: Arc::clone(&dm), delta: None })
            .unwrap()
            .unwrap();
        assert_eq!(ack.name(), "ack");
        // the shard state moved exactly α·Δ
        let state = node.handle(NodeMessage::GetState).unwrap().unwrap();
        match state {
            NodeMessage::State { beta_local, margins_crc } => {
                let mut want = vec![0f32; beta_local.len()];
                result.delta_local.add_scaled_into(&mut want, 0.5);
                for (a, b) in beta_local.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                let mut margins = vec![0f32; 120];
                dm.add_scaled_into(&mut margins, 0.5);
                assert_eq!(margins_crc, crc_f32(&margins));
            }
            other => panic!("expected state, got {}", other.name()),
        }
    }

    #[test]
    fn explicit_merged_delta_applies_only_owned_columns() {
        let (mut node, _ds) = node_for(1, 3); // owns global cols 1, 4, 7, ...
        // run one sweep so last_delta is non-empty — the explicit path must
        // ignore it and use the provided merged Δβ instead
        node.handle(NodeMessage::Sweep {
            lam: 0.5,
            nu: 1e-6,
            l2: 0.0,
            recycle: Default::default(),
        })
        .unwrap();
        let mut merged = SparseVec::new(24);
        merged.push(0, 10.0); // not owned
        merged.push(1, 2.0); // owned (local 0)
        merged.push(7, -4.0); // owned (local 2)
        merged.push(9, 5.0); // not owned
        let before = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, .. } => beta_local,
            _ => unreachable!(),
        };
        node.handle(NodeMessage::Apply {
            alpha: 0.5,
            dmargins: Arc::new(SparseVec::new(120)),
            delta: Some(Arc::new(merged)),
        })
        .unwrap();
        let after = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, .. } => beta_local,
            _ => unreachable!(),
        };
        assert_eq!(after[0], before[0] + 1.0, "global col 1 is local 0");
        assert_eq!(after[2], before[2] - 2.0, "global col 7 is local 2");
        for l in [1usize, 3, 4, 5, 6, 7] {
            if l < after.len() && l != 0 && l != 2 {
                assert_eq!(after[l].to_bits(), before[l].to_bits(), "local {l}");
            }
        }
    }

    #[test]
    fn set_state_validates_shapes_and_resets_last_delta() {
        let (mut node, _ds) = node_for(0, 2);
        let local_p = node.beta_local.len();
        // wrong shapes error
        assert!(node
            .handle(NodeMessage::SetState {
                beta_local: vec![0.0; local_p + 1],
                margins: Arc::new(vec![0.0; 120]),
            })
            .is_err());
        // correct shapes install bit-for-bit
        let beta: Vec<f32> = (0..local_p).map(|i| i as f32 * 0.25 - 1.0).collect();
        let margins: Vec<f32> = (0..120).map(|i| (i as f32).sin()).collect();
        node.handle(NodeMessage::SetState {
            beta_local: beta.clone(),
            margins: Arc::new(margins.clone()),
        })
        .unwrap();
        match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => {
                for (a, b) in beta_local.iter().zip(&beta) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(margins_crc, crc_f32(&margins));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn unexpected_messages_error() {
        let (mut node, _ds) = node_for(0, 2);
        assert!(node
            .handle(NodeMessage::Welcome {
                family: "logistic".into(),
                alpha: 1.0,
                topology: None,
            })
            .is_err());
        assert!(node.handle(NodeMessage::Ack).is_err());
        assert!(matches!(node.handle(NodeMessage::Shutdown), Ok(None)));
    }

    #[test]
    fn ping_answers_pong_without_touching_state() {
        let (mut node, _ds) = node_for(0, 2);
        let before = match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => (beta_local, margins_crc),
            _ => unreachable!(),
        };
        let reply = node.handle(NodeMessage::Ping).unwrap().unwrap();
        assert_eq!(reply.name(), "pong");
        match node.handle(NodeMessage::GetState).unwrap().unwrap() {
            NodeMessage::State { beta_local, margins_crc } => {
                assert_eq!(beta_local, before.0);
                assert_eq!(margins_crc, before.1);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn join_message_carries_shard_identity() {
        let (node, _ds) = node_for(1, 2);
        match node.join_message("10.0.0.7:41000") {
            NodeMessage::Join {
                machine,
                n,
                p,
                local_features,
                cols_checksum,
                engine,
                family,
                listen_addr,
            } => {
                assert_eq!(machine, 1);
                assert_eq!(n, 120);
                assert_eq!(p, 24);
                assert_eq!(local_features, 12);
                let cols: Vec<u32> = (0..24u32).filter(|c| c % 2 == 1).collect();
                assert_eq!(cols_checksum, crc_u32(&cols));
                assert_eq!(engine, "native");
                assert_eq!(family, "logistic");
                assert_eq!(listen_addr, "10.0.0.7:41000");
            }
            other => panic!("expected join, got {}", other.name()),
        }
    }
}
