//! Tree AllReduce (Alg 4 step 3). The paper uses Vowpal Wabbit's
//! MPI_AllReduce-style tree: reduce up a binary tree, broadcast down —
//! `2·ceil(log2 M)` rounds, each moving the full vector, which is where the
//! `O((n + p) ln M)` communication bound comes from.
//!
//! We compute the sum exactly (deterministic pairwise order, so repeated
//! runs bit-match) and charge the simulated network per message: every
//! pair message in the reduce phase, and one message per concurrent
//! broadcast round (the broadcast fan-out is modeled by its critical path,
//! so its *byte* count is per-round, not per-edge — a per-node view of the
//! paper's `O((n + p) ln M)` bound; inherited from the original dense
//! model and pinned by the byte-accounting tests below).
//!
//! ## Sparse wire format
//!
//! The paper's bound assumes dense vectors, but d-GLMNET's own sparsity
//! precautions (§2) mean Δβ — and at high λ even ΔβᵀX — carry only a
//! handful of non-zeros per iteration. [`TreeAllReduce::sum_sparse_into`]
//! therefore ships [`SparseVec`] messages: each edge moves
//! `nnz · (4 + 4)` bytes (a `u32` index plus an `f32` value per entry,
//! [`SPARSE_ENTRY_BYTES`]), and tree nodes combine children with a sorted
//! sparse-sparse merge in `f64`, in the same deterministic pairwise order
//! as the dense path — so sparse and dense reductions produce *identical*
//! sums.
//!
//! ## Dense fallback
//!
//! Sparse entries cost 8 bytes against 4 for a dense slot, so once the
//! combined contribution density crosses
//! [`TreeAllReduce::DENSE_FALLBACK_DENSITY`] (total nnz across machines
//! relative to `dim`; well under the 0.5 break-even so no message is ever
//! charged more than its dense equivalent) the reduction densifies and
//! charges `dim · 4` bytes per edge, exactly like the classic dense path.
//! A threshold of `0.0` (see [`TreeAllReduce::with_density_threshold`])
//! forces the dense path — the ablation baseline benchmarks use this.
//!
//! All intermediate state lives in a caller-owned [`AllReduceScratch`], so
//! steady-state reductions are allocation-free.

use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::data::sparse::{SparseVec, SPARSE_ENTRY_BYTES};

/// The result of one allreduce: tree shape plus simulated cost.
#[derive(Debug, Clone)]
pub struct AllReduceOutcome {
    pub rounds: usize,
    pub bytes_moved: u64,
    pub simulated_secs: f64,
}

impl AllReduceOutcome {
    fn free() -> Self {
        Self { rounds: 0, bytes_moved: 0, simulated_secs: 0.0 }
    }
}

/// Reusable buffers for [`TreeAllReduce::sum_sparse_into`]: per-node sparse
/// accumulators (`f64` for associativity-stable sums; sparse mode only), a
/// merge double-buffer, dense-fallback accumulators (dense mode only), and
/// the active-node lists. Capacities persist across calls, so
/// per-iteration reductions stop allocating once the high-water mark is
/// reached.
#[derive(Debug, Default)]
pub struct AllReduceScratch {
    acc_idx: Vec<Vec<u32>>,
    acc_val: Vec<Vec<f64>>,
    tmp_idx: Vec<u32>,
    tmp_val: Vec<f64>,
    dense: Vec<Vec<f64>>,
    active: Vec<usize>,
    next_active: Vec<usize>,
}

/// Tree AllReduce over M in-process per-machine buffers.
#[derive(Debug)]
pub struct TreeAllReduce {
    pub model: NetworkModel,
    /// Combined-density threshold above which [`sum_sparse_into`]
    /// (see [`TreeAllReduce::sum_sparse_into`]) falls back to the dense
    /// wire format. `<= 0.0` forces dense.
    pub dense_fallback_density: f64,
}

impl TreeAllReduce {
    /// Default switch-to-dense threshold: total contribution nnz / dim.
    pub const DENSE_FALLBACK_DENSITY: f64 = 0.25;

    pub fn new(model: NetworkModel) -> Self {
        Self { model, dense_fallback_density: Self::DENSE_FALLBACK_DENSITY }
    }

    /// Override the dense-fallback threshold (`0.0` = always dense — the
    /// ablation baseline; `f64::INFINITY` = never fall back).
    pub fn with_density_threshold(model: NetworkModel, threshold: f64) -> Self {
        Self { model, dense_fallback_density: threshold }
    }

    /// Sum `contributions` (all same length) into one dense vector,
    /// charging the ledger as a binary-tree reduce + broadcast. Pairwise
    /// reduction order is fixed (machine 2k + 2k+1), making the float sum
    /// deterministic. Compatibility wrapper over the scratch-based path —
    /// per-pass loops should hold an [`AllReduceScratch`] and call
    /// [`TreeAllReduce::sum_dense_into`] (or, for sparse payloads,
    /// [`TreeAllReduce::sum_sparse_into`]) instead.
    pub fn sum(
        &self,
        contributions: &[Vec<f32>],
        ledger: &NetworkLedger,
    ) -> (Vec<f32>, AllReduceOutcome) {
        let mut scratch = AllReduceScratch::default();
        let mut out = Vec::new();
        let outcome = self.sum_dense_into(contributions, ledger, &mut scratch, &mut out);
        (out, outcome)
    }

    /// Dense-wire AllReduce into a caller-reused output buffer, with all
    /// intermediate state in `scratch` — the allocation-free call path for
    /// callers whose contributions are already dense (the online baseline's
    /// once-per-pass weight averaging). No sparse conversion anywhere:
    /// contributions load straight into the f64 tree accumulators. Charges
    /// `dim · 4` bytes per edge, identical (bytes, rounds, and bit-exact
    /// sums) to the classic dense path [`TreeAllReduce::sum`] wraps.
    pub fn sum_dense_into(
        &self,
        contributions: &[Vec<f32>],
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut Vec<f32>,
    ) -> AllReduceOutcome {
        assert!(!contributions.is_empty(), "allreduce needs at least one contribution");
        let m = contributions.len();
        let dim = contributions[0].len();
        for c in contributions {
            assert_eq!(c.len(), dim, "ragged allreduce contribution");
        }
        out.clear();
        if m == 1 {
            // single machine: free reduction, straight copy (f32 exact)
            out.extend_from_slice(&contributions[0]);
            return AllReduceOutcome::free();
        }
        if scratch.dense.len() < m {
            scratch.dense.resize_with(m, Vec::new);
        }
        for (k, c) in contributions.iter().enumerate() {
            let d = &mut scratch.dense[k];
            d.clear();
            d.extend(c.iter().map(|&v| v as f64));
        }
        let (root, outcome) = self.dense_tree(m, dim, ledger, scratch);
        out.extend(scratch.dense[root].iter().map(|&v| v as f32));
        outcome
    }

    /// Sum sparse `contributions` (each of logical length `dim`) into
    /// `out`, charging the ledger for the actual payload of every edge:
    /// `nnz · 8` bytes per sparse message, or `dim · 4` after the dense
    /// fallback kicks in. The merged result is written into `out` (sorted,
    /// unique indices); `scratch` carries all intermediate state.
    pub fn sum_sparse_into<'a>(
        &self,
        contributions: impl ExactSizeIterator<Item = &'a SparseVec> + Clone,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        let m = contributions.len();
        assert!(m > 0, "allreduce needs at least one contribution");

        // ---- cheap first pass: validate dims, pick the wire format ----
        let mut total_nnz = 0usize;
        for c in contributions.clone() {
            assert_eq!(c.dim, dim, "ragged allreduce contribution");
            total_nnz += c.nnz();
        }

        if m == 1 {
            // single machine: free reduction, straight copy (f32 exact)
            let c = contributions.clone().next().unwrap();
            out.clear(dim);
            out.indices.extend_from_slice(&c.indices);
            out.values.extend_from_slice(&c.values);
            return AllReduceOutcome::free();
        }

        let dense_mode = self.dense_fallback_density <= 0.0
            || total_nnz as f64 > self.dense_fallback_density * dim as f64;
        if dense_mode {
            // densify straight from the contributions — no sparse staging
            // copy on the (common at low λ) dense-fallback path
            if scratch.dense.len() < m {
                scratch.dense.resize_with(m, Vec::new);
            }
            for (k, c) in contributions.enumerate() {
                let d = &mut scratch.dense[k];
                d.clear();
                d.resize(dim, 0.0);
                for (i, v) in c.iter() {
                    d[i as usize] = v as f64;
                }
            }
            self.reduce_dense(m, dim, ledger, scratch, out)
        } else {
            // load the sorted f64 accumulators for the sparse merges
            if scratch.acc_idx.len() < m {
                scratch.acc_idx.resize_with(m, Vec::new);
                scratch.acc_val.resize_with(m, Vec::new);
            }
            for (k, c) in contributions.enumerate() {
                let idx = &mut scratch.acc_idx[k];
                let val = &mut scratch.acc_val[k];
                idx.clear();
                val.clear();
                idx.extend_from_slice(&c.indices);
                val.extend(c.values.iter().map(|&v| v as f64));
            }
            self.reduce_sparse(m, dim, ledger, scratch, out)
        }
    }

    /// Sparse tree reduce: sorted merges, `nnz · 8`-byte edges.
    ///
    /// NOTE: the pairing/round/broadcast walk must stay in lockstep with
    /// [`TreeAllReduce::reduce_dense`] — the sparse-vs-dense equivalence
    /// guarantees (identical sums, identical trajectories) depend on both
    /// summing in exactly the same pairwise order. The equivalence tests
    /// in `tests/sparse_allreduce.rs` pin this down.
    fn reduce_sparse(
        &self,
        m: usize,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        scratch.active.clear();
        scratch.active.extend(0..m);
        let mut rounds = 0usize;
        let mut bytes = 0u64;
        let mut secs_total = 0f64;

        // ---- reduce up the tree ----
        while scratch.active.len() > 1 {
            rounds += 1;
            // all pair-messages in a round are concurrent: charge the max,
            // not the sum, for time; bytes are summed.
            let mut round_secs = 0f64;
            scratch.next_active.clear();
            let pairs = scratch.active.len() / 2;
            for t in 0..pairs {
                let a = scratch.active[2 * t];
                let b = scratch.active[2 * t + 1];
                let msg_bytes = scratch.acc_idx[b].len() as u64 * SPARSE_ENTRY_BYTES;
                let t_secs = ledger.record(&self.model, msg_bytes);
                bytes += msg_bytes;
                round_secs = round_secs.max(t_secs);
                merge_sorted_into(
                    &scratch.acc_idx[a],
                    &scratch.acc_val[a],
                    &scratch.acc_idx[b],
                    &scratch.acc_val[b],
                    &mut scratch.tmp_idx,
                    &mut scratch.tmp_val,
                );
                std::mem::swap(&mut scratch.acc_idx[a], &mut scratch.tmp_idx);
                std::mem::swap(&mut scratch.acc_val[a], &mut scratch.tmp_val);
                scratch.next_active.push(a);
            }
            if scratch.active.len() % 2 == 1 {
                scratch.next_active.push(*scratch.active.last().unwrap());
            }
            std::mem::swap(&mut scratch.active, &mut scratch.next_active);
            secs_total += round_secs;
        }

        // ---- broadcast down: same tree depth, same concurrency ----
        let root = scratch.active[0];
        let root_bytes = scratch.acc_idx[root].len() as u64 * SPARSE_ENTRY_BYTES;
        let depth = (m as f64).log2().ceil() as usize;
        for _ in 0..depth {
            let t = ledger.record(&self.model, root_bytes);
            bytes += root_bytes;
            secs_total += t;
        }

        out.clear(dim);
        for (i, &v) in scratch.acc_idx[root].iter().zip(&scratch.acc_val[root]) {
            out.push(*i, v as f32);
        }
        AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total }
    }

    /// Dense tree reduce over the fallback accumulators: `dim · 4`-byte
    /// edges, identical charging (and identical f64 sums) to the classic
    /// dense AllReduce. Keep the tree walk in lockstep with
    /// [`TreeAllReduce::reduce_sparse`] (see the note there).
    fn reduce_dense(
        &self,
        m: usize,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        let (root, outcome) = self.dense_tree(m, dim, ledger, scratch);
        out.clear(dim);
        for (i, &v) in scratch.dense[root].iter().enumerate() {
            if v != 0.0 {
                out.push(i as u32, v as f32);
            }
        }
        outcome
    }

    /// The shared dense tree walk over `scratch.dense[0..m]`: reduce up,
    /// broadcast down, charging `dim · 4` bytes per edge. Leaves the merged
    /// f64 sums in `scratch.dense[root]` and returns the root index.
    fn dense_tree(
        &self,
        m: usize,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
    ) -> (usize, AllReduceOutcome) {
        let vec_bytes = (dim * std::mem::size_of::<f32>()) as u64;
        scratch.active.clear();
        scratch.active.extend(0..m);
        let mut rounds = 0usize;
        let mut bytes = 0u64;
        let mut secs_total = 0f64;

        while scratch.active.len() > 1 {
            rounds += 1;
            let mut round_secs = 0f64;
            scratch.next_active.clear();
            let pairs = scratch.active.len() / 2;
            for t in 0..pairs {
                let a = scratch.active[2 * t];
                let b = scratch.active[2 * t + 1];
                let t_secs = ledger.record(&self.model, vec_bytes);
                bytes += vec_bytes;
                round_secs = round_secs.max(t_secs);
                let (lo, hi) = scratch.dense.split_at_mut(a.max(b));
                let (dst, src) = if a < b { (&mut lo[a], &hi[0]) } else { (&mut hi[0], &lo[b]) };
                for (x, y) in dst.iter_mut().zip(src.iter()) {
                    *x += *y;
                }
                scratch.next_active.push(a);
            }
            if scratch.active.len() % 2 == 1 {
                scratch.next_active.push(*scratch.active.last().unwrap());
            }
            std::mem::swap(&mut scratch.active, &mut scratch.next_active);
            secs_total += round_secs;
        }

        let depth = (m as f64).log2().ceil() as usize;
        for _ in 0..depth {
            let t = ledger.record(&self.model, vec_bytes);
            bytes += vec_bytes;
            secs_total += t;
        }

        let root = scratch.active[0];
        (root, AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total })
    }
}

/// Two-pointer merge of two sorted sparse accumulators into `(oi, ov)`;
/// shared indices sum in `f64` (`a + b`, the same order as the dense path).
fn merge_sorted_into(
    ai: &[u32],
    av: &[f64],
    bi: &[u32],
    bv: &[f64],
    oi: &mut Vec<u32>,
    ov: &mut Vec<f64>,
) {
    oi.clear();
    ov.clear();
    oi.reserve(ai.len() + bi.len());
    ov.reserve(av.len() + bv.len());
    let (mut x, mut y) = (0usize, 0usize);
    while x < ai.len() && y < bi.len() {
        match ai[x].cmp(&bi[y]) {
            std::cmp::Ordering::Less => {
                oi.push(ai[x]);
                ov.push(av[x]);
                x += 1;
            }
            std::cmp::Ordering::Greater => {
                oi.push(bi[y]);
                ov.push(bv[y]);
                y += 1;
            }
            std::cmp::Ordering::Equal => {
                oi.push(ai[x]);
                ov.push(av[x] + bv[y]);
                x += 1;
                y += 1;
            }
        }
    }
    oi.extend_from_slice(&ai[x..]);
    ov.extend_from_slice(&av[x..]);
    oi.extend_from_slice(&bi[y..]);
    ov.extend_from_slice(&bv[y..]);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_serial(contribs: &[Vec<f32>]) -> Vec<f64> {
        let mut acc = vec![0f64; contribs[0].len()];
        for c in contribs {
            for (a, &x) in acc.iter_mut().zip(c) {
                *a += x as f64;
            }
        }
        acc
    }

    #[test]
    fn allreduce_equals_serial_sum() {
        for m in [1usize, 2, 3, 5, 8, 16] {
            let contribs: Vec<Vec<f32>> = (0..m)
                .map(|k| (0..50).map(|i| ((k * 50 + i) as f32).sin()).collect())
                .collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (got, outcome) = ar.sum(&contribs, &ledger);
            let want = sum_serial(&contribs);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-4, "m={m}");
            }
            if m > 1 {
                assert_eq!(outcome.rounds, (m as f64).log2().ceil() as usize);
                assert!(outcome.bytes_moved > 0);
            }
        }
    }

    #[test]
    fn single_machine_is_free_reduction() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let (out, outcome) = ar.sum(&[vec![1.0, 2.0]], &ledger);
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(outcome.rounds, 0);
    }

    #[test]
    fn bytes_scale_log_in_machines() {
        // O((n+p) ln M): doubling M adds ~one round, not ~double bytes/machine
        let n = 10_000usize;
        let cost = |m: usize| {
            let contribs: Vec<Vec<f32>> = (0..m).map(|_| vec![1f32; n]).collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (_, o) = ar.sum(&contribs, &ledger);
            o.simulated_secs
        };
        let t4 = cost(4);
        let t16 = cost(16);
        // log2(16)/log2(4) = 2: simulated time should grow ~2x, not 4x
        assert!(t16 / t4 < 2.6, "t4={t4} t16={t16}");
        assert!(t16 > t4);
    }

    #[test]
    fn dense_scratch_path_matches_sum_wrapper() {
        // the baselines' allocation-free call path: identical sums, bytes
        // and rounds to the compat wrapper, stable across scratch reuse
        let contribs: Vec<Vec<f32>> = (0..5)
            .map(|k| (0..40).map(|i| ((k * 40 + i) as f32).cos()).collect())
            .collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let (want, o_want) = ar.sum(&contribs, &NetworkLedger::new());
        let mut scratch = AllReduceScratch::default();
        let mut out = Vec::new();
        for _ in 0..2 {
            let ledger = NetworkLedger::new();
            let o = ar.sum_dense_into(&contribs, &ledger, &mut scratch, &mut out);
            assert_eq!(out, want);
            assert_eq!(o.bytes_moved, o_want.bytes_moved);
            assert_eq!(o.rounds, o_want.rounds);
            assert_eq!(ledger.total_bytes(), o.bytes_moved);
        }
        // single machine stays a free reduction
        let one = vec![vec![1.5f32, -2.0]];
        let o = ar.sum_dense_into(&one, &NetworkLedger::new(), &mut scratch, &mut out);
        assert_eq!(out, vec![1.5, -2.0]);
        assert_eq!(o.rounds, 0);
        assert_eq!(o.bytes_moved, 0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_contributions_panic() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        ar.sum(&[vec![1.0], vec![1.0, 2.0]], &ledger);
    }

    fn sparse_of(dense: &[f32]) -> SparseVec {
        SparseVec::from_dense(dense)
    }

    #[test]
    fn sparse_sum_matches_dense_sum_exactly() {
        // three ragged-sparsity contributions over dim = 12, incl. overlap
        let dense: Vec<Vec<f32>> = vec![
            vec![0., 1., 0., 0., 2., 0., 0., 0., 0., 0., -1., 0.],
            vec![0., 0., 0., 0., 3., 0., 0.5, 0., 0., 0., 0., 0.],
            vec![4., 0., 0., 0., 0., 0., 0., 0., 0., 0., 1., 0.],
        ];
        let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(12);
        let o = ar.sum_sparse_into(sparse.iter(), 12, &ledger, &mut scratch, &mut out);
        let (dense_out, _) = ar.sum(&dense, &NetworkLedger::new());
        assert_eq!(out.to_dense(), dense_out);
        assert!(o.bytes_moved > 0);
        assert_eq!(o.rounds, 2);
    }

    #[test]
    fn sparse_wire_charges_payload_not_dim() {
        // two contributions with 2 nnz each over a huge dim: the reduce edge
        // carries 2 entries (16 bytes) and each broadcast edge the merged 4
        let a = {
            let mut v = SparseVec::new(1_000_000);
            v.push(10, 1.0);
            v.push(20, 2.0);
            v
        };
        let b = {
            let mut v = SparseVec::new(1_000_000);
            v.push(15, 3.0);
            v.push(25, 4.0);
            v
        };
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o =
            ar.sum_sparse_into([&a, &b].into_iter(), 1_000_000, &ledger, &mut scratch, &mut out);
        // reduce: b's 2 entries = 16 bytes; broadcast: 1 round × 4 entries = 32
        assert_eq!(o.bytes_moved, 16 + 32);
        assert_eq!(out.nnz(), 4);
        assert_eq!(ledger.total_bytes(), o.bytes_moved);
    }

    #[test]
    fn dense_fallback_above_density_threshold() {
        let dim = 100usize;
        // combined density 0.6 > 0.25 threshold -> dense wire format
        let a = sparse_of(&(0..dim).map(|i| if i < 30 { 1.0 } else { 0.0 }).collect::<Vec<_>>());
        let b = sparse_of(&(0..dim).map(|i| if i >= 70 { 2.0 } else { 0.0 }).collect::<Vec<_>>());
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o = ar.sum_sparse_into([&a, &b].into_iter(), dim, &ledger, &mut scratch, &mut out);
        // dense edges: (1 reduce + 1 broadcast) × dim × 4 bytes
        assert_eq!(o.bytes_moved, 2 * dim as u64 * 4);
        assert_eq!(out.nnz(), 60);
    }

    #[test]
    fn all_zero_contributions_cost_nothing_on_the_wire() {
        let contribs: Vec<SparseVec> = (0..4).map(|_| SparseVec::new(50)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o = ar.sum_sparse_into(contribs.iter(), 50, &ledger, &mut scratch, &mut out);
        assert_eq!(o.bytes_moved, 0);
        assert_eq!(out.nnz(), 0);
        assert_eq!(out.dim, 50);
    }

    #[test]
    fn scratch_reuse_is_stable_across_calls() {
        // same reduction twice through one scratch must give identical
        // results and identical ledger charges (buffers fully reset)
        // ~11 nnz per contribution over dim 400: total density ~0.14 stays
        // under the 0.25 fallback, so this runs the sparse merge path
        let dense: Vec<Vec<f32>> = (0..5)
            .map(|k| {
                (0..400).map(|i| if (i + k) % 37 == 0 { (k + i) as f32 } else { 0.0 }).collect()
            })
            .collect();
        let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let mut scratch = AllReduceScratch::default();
        let mut out1 = SparseVec::new(0);
        let mut out2 = SparseVec::new(0);
        let l1 = NetworkLedger::new();
        let o1 = ar.sum_sparse_into(sparse.iter(), 400, &l1, &mut scratch, &mut out1);
        let l2 = NetworkLedger::new();
        let o2 = ar.sum_sparse_into(sparse.iter(), 400, &l2, &mut scratch, &mut out2);
        assert_eq!(out1, out2);
        assert_eq!(o1.bytes_moved, o2.bytes_moved);
        let want = sum_serial(&dense);
        let got = out1.to_dense();
        for i in 0..400 {
            assert!((got[i] as f64 - want[i]).abs() < 1e-5, "i = {i}");
        }
    }
}
