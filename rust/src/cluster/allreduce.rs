//! Tree AllReduce (Alg 4 step 3). The paper uses Vowpal Wabbit's
//! MPI_AllReduce-style tree: reduce up a binary tree, broadcast down —
//! `2·ceil(log2 M)` rounds, each moving the full vector, which is where the
//! `O((n + p) ln M)` communication bound comes from.
//!
//! We compute the sum exactly (deterministic pairwise order, so repeated
//! runs bit-match) and charge the simulated network for every edge crossed.

use crate::cluster::network::{NetworkLedger, NetworkModel};

/// The result of one allreduce: the summed vector plus its simulated cost.
#[derive(Debug, Clone)]
pub struct AllReduceOutcome {
    pub rounds: usize,
    pub bytes_moved: u64,
    pub simulated_secs: f64,
}

/// Tree AllReduce over M in-process per-machine buffers.
#[derive(Debug)]
pub struct TreeAllReduce {
    pub model: NetworkModel,
}

impl TreeAllReduce {
    pub fn new(model: NetworkModel) -> Self {
        Self { model }
    }

    /// Sum `contributions` (all same length) into one vector, charging the
    /// ledger as a binary-tree reduce + broadcast. Pairwise reduction order
    /// is fixed (machine 2k + 2k+1), making the float sum deterministic.
    pub fn sum(
        &self,
        contributions: &[Vec<f32>],
        ledger: &NetworkLedger,
    ) -> (Vec<f32>, AllReduceOutcome) {
        assert!(!contributions.is_empty());
        let len = contributions[0].len();
        for c in contributions {
            assert_eq!(c.len(), len, "ragged allreduce contribution");
        }
        let m = contributions.len();
        let vec_bytes = (len * std::mem::size_of::<f32>()) as u64;

        let mut layer: Vec<Vec<f64>> = contributions
            .iter()
            .map(|c| c.iter().map(|&x| x as f64).collect())
            .collect();
        let mut rounds = 0usize;
        let mut bytes = 0u64;
        let mut secs_total = 0f64;

        // ---- reduce up the tree ----
        while layer.len() > 1 {
            rounds += 1;
            // all pair-messages in a round are concurrent: charge the max,
            // not the sum, for time; bytes are summed.
            let pairs = layer.len() / 2;
            let mut round_secs = 0f64;
            let mut next: Vec<Vec<f64>> = Vec::with_capacity(pairs + layer.len() % 2);
            let mut it = layer.into_iter();
            loop {
                match (it.next(), it.next()) {
                    (Some(mut a), Some(b)) => {
                        for (x, y) in a.iter_mut().zip(&b) {
                            *x += *y;
                        }
                        let t = ledger.record(&self.model, vec_bytes);
                        bytes += vec_bytes;
                        round_secs = round_secs.max(t);
                        next.push(a);
                    }
                    (Some(a), None) => {
                        next.push(a);
                        break;
                    }
                    _ => break,
                }
            }
            secs_total += round_secs;
            layer = next;
        }

        // ---- broadcast down: same tree depth, same concurrency ----
        let depth = (m as f64).log2().ceil() as usize;
        for _ in 0..depth {
            // each broadcast round fans out to at most double the holders
            let t = ledger.record(&self.model, vec_bytes);
            bytes += vec_bytes;
            secs_total += t;
        }

        let root = layer.pop().unwrap();
        let out: Vec<f32> = root.into_iter().map(|x| x as f32).collect();
        (out, AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_serial(contribs: &[Vec<f32>]) -> Vec<f64> {
        let mut acc = vec![0f64; contribs[0].len()];
        for c in contribs {
            for (a, &x) in acc.iter_mut().zip(c) {
                *a += x as f64;
            }
        }
        acc
    }

    #[test]
    fn allreduce_equals_serial_sum() {
        for m in [1usize, 2, 3, 5, 8, 16] {
            let contribs: Vec<Vec<f32>> = (0..m)
                .map(|k| (0..50).map(|i| ((k * 50 + i) as f32).sin()).collect())
                .collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (got, outcome) = ar.sum(&contribs, &ledger);
            let want = sum_serial(&contribs);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-4, "m={m}");
            }
            if m > 1 {
                assert_eq!(outcome.rounds, (m as f64).log2().ceil() as usize);
                assert!(outcome.bytes_moved > 0);
            }
        }
    }

    #[test]
    fn single_machine_is_free_reduction() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let (out, outcome) = ar.sum(&[vec![1.0, 2.0]], &ledger);
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(outcome.rounds, 0);
    }

    #[test]
    fn bytes_scale_log_in_machines() {
        // O((n+p) ln M): doubling M adds ~one round, not ~double bytes/machine
        let n = 10_000usize;
        let cost = |m: usize| {
            let contribs: Vec<Vec<f32>> = (0..m).map(|_| vec![1f32; n]).collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (_, o) = ar.sum(&contribs, &ledger);
            o.simulated_secs
        };
        let t4 = cost(4);
        let t16 = cost(16);
        // log2(16)/log2(4) = 2: simulated time should grow ~2x, not 4x
        assert!(t16 / t4 < 2.6, "t4={t4} t16={t16}");
        assert!(t16 > t4);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_contributions_panic() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        ar.sum(&[vec![1.0], vec![1.0, 2.0]], &ledger);
    }
}
