//! Tree AllReduce (Alg 4 step 3) — the shared tree engine behind the
//! `cluster::comm` collectives. The paper uses Vowpal Wabbit's
//! MPI_AllReduce-style tree: reduce up a binary tree, broadcast down —
//! `2·ceil(log2 M)` rounds, which is where the `O((n + p) ln M)`
//! communication bound comes from.
//!
//! We compute the sum exactly (deterministic pairwise order, so repeated
//! runs bit-match) and charge the simulated network per message:
//!
//! * **Reduce phase** — every pair message carries the child's payload,
//!   encoded with the cheapest codec the [`CodecPolicy`] allows for the
//!   message class (see [`crate::cluster::codec`] — per-message byte-cost
//!   selection replaced the old 0.25 combined-density threshold).
//! * **Broadcast phase** — the merged vector retraces the tree with **one
//!   message per edge** (`M - 1` edges total), levels concurrent for the
//!   time model. (The seed charged one message per concurrent round, which
//!   undercounted fan-out bytes ~4× at M = 16; the byte-pinning tests here
//!   and in `tests/sparse_allreduce.rs` pin the per-edge accounting.)
//!
//! Tree-node merges are handed to the
//! [`TaskExecutor`](crate::cluster::comm::TaskExecutor) in the call's
//! [`CommCtx`] — the solver passes its `WorkerPool`, so merge work runs on
//! worker threads, never the leader. Merges are sorted sparse-sparse
//! `f64` unions in a fixed pairwise order (machine 2k with 2k+1), so the
//! result is bit-identical for every executor and every codec choice
//! except the opt-in lossy f16 codec, which quantizes a message's values
//! exactly as the wire would.
//!
//! All intermediate state lives in a caller-owned [`AllReduceScratch`];
//! buffer capacities persist across calls.

use std::sync::mpsc;

use crate::cluster::codec::{quantize_f16_f64, CodecPolicy, MessageClass, WireCodec};
use crate::cluster::comm::{CommCtx, Job, SerialExecutor};
use crate::cluster::network::{NetworkLedger, NetworkModel};
use crate::data::sparse::SparseVec;

/// The result of one allreduce: tree shape plus simulated cost.
#[derive(Debug, Clone)]
pub struct AllReduceOutcome {
    /// Reduce-phase rounds (`ceil(log2 M)`; the broadcast mirrors them).
    pub rounds: usize,
    pub bytes_moved: u64,
    pub simulated_secs: f64,
}

impl AllReduceOutcome {
    fn free() -> Self {
        Self { rounds: 0, bytes_moved: 0, simulated_secs: 0.0 }
    }
}

/// Reusable buffers for the tree engine: per-node sparse accumulators
/// (`f64` for associativity-stable sums), a pool of spare merge buffers
/// that round-trip through the executor, dense accumulators (for the
/// dense-contribution API the baselines use), and the active-node lists.
/// Capacities persist across calls, so per-iteration exchanges stop
/// allocating large buffers once the high-water mark is reached.
#[derive(Debug, Default)]
pub struct AllReduceScratch {
    acc_idx: Vec<Vec<u32>>,
    acc_val: Vec<Vec<f64>>,
    spare_idx: Vec<Vec<u32>>,
    spare_val: Vec<Vec<f64>>,
    dense: Vec<Vec<f64>>,
    active: Vec<usize>,
    next_active: Vec<usize>,
    pairs_per_round: Vec<usize>,
}

/// Tree AllReduce over M in-process per-machine buffers. The sparse entry
/// point is [`Collective::exchange`](crate::cluster::comm::Collective);
/// [`TreeAllReduce::sum`] / [`TreeAllReduce::sum_dense_into`] serve callers
/// whose contributions are dense vectors (the online baseline's weight
/// averaging), and [`TreeAllReduce::sum_sparse_into`] is the serial-executor
/// compatibility wrapper over the sparse engine.
#[derive(Debug)]
pub struct TreeAllReduce {
    pub model: NetworkModel,
}

impl TreeAllReduce {
    pub fn new(model: NetworkModel) -> Self {
        Self { model }
    }

    /// Sum `contributions` (all same length) into one dense vector,
    /// charging the ledger as a binary-tree reduce + per-edge broadcast.
    /// Pairwise reduction order is fixed (machine 2k + 2k+1), making the
    /// float sum deterministic. Compatibility wrapper over the
    /// scratch-based [`TreeAllReduce::sum_dense_into`].
    pub fn sum(
        &self,
        contributions: &[Vec<f32>],
        ledger: &NetworkLedger,
    ) -> (Vec<f32>, AllReduceOutcome) {
        let mut scratch = AllReduceScratch::default();
        let mut out = Vec::new();
        let outcome = self.sum_dense_into(contributions, ledger, &mut scratch, &mut out);
        (out, outcome)
    }

    /// Dense-wire AllReduce into a caller-reused output buffer, with all
    /// intermediate state in `scratch` — the allocation-free call path for
    /// callers whose contributions are already dense (the online baseline's
    /// once-per-pass weight averaging). Contributions load straight into
    /// the f64 tree accumulators and merges run inline (one dense add per
    /// pass is not worth a worker round-trip). Charges `dim · 4` bytes per
    /// edge, reduce and broadcast alike.
    pub fn sum_dense_into(
        &self,
        contributions: &[Vec<f32>],
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut Vec<f32>,
    ) -> AllReduceOutcome {
        assert!(!contributions.is_empty(), "allreduce needs at least one contribution");
        let m = contributions.len();
        let dim = contributions[0].len();
        for c in contributions {
            assert_eq!(c.len(), dim, "ragged allreduce contribution");
        }
        out.clear();
        if m == 1 {
            // single machine: free reduction, straight copy (f32 exact)
            out.extend_from_slice(&contributions[0]);
            return AllReduceOutcome::free();
        }
        if scratch.dense.len() < m {
            scratch.dense.resize_with(m, Vec::new);
        }
        for (k, c) in contributions.iter().enumerate() {
            let d = &mut scratch.dense[k];
            d.clear();
            d.extend(c.iter().map(|&v| v as f64));
        }
        let (root, outcome) = self.dense_tree(m, dim, ledger, scratch);
        out.extend(scratch.dense[root].iter().map(|&v| v as f32));
        outcome
    }

    /// Sum sparse `contributions` (each of logical length `dim`) into
    /// `out`, charging the ledger for the actual payload of every edge
    /// under the lossless codecs. Serial-executor compatibility wrapper
    /// over the `cluster::comm` engine — the solver hot path goes through
    /// [`Collective::exchange`](crate::cluster::comm::Collective) with its
    /// worker-pool executor instead.
    pub fn sum_sparse_into<'a>(
        &self,
        contributions: impl ExactSizeIterator<Item = &'a SparseVec> + Clone,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
        out: &mut SparseVec,
    ) -> AllReduceOutcome {
        let refs: Vec<&SparseVec> = contributions.collect();
        let ctx = CommCtx {
            ledger,
            policy: CodecPolicy::lossless(),
            class: MessageClass::Margins,
            exec: &SerialExecutor,
            charge: true,
            broadcast: true,
        };
        run_sparse_exchange(&self.model, refs.len(), &|k| refs[k], dim, &ctx, scratch, out)
    }

    /// The shared dense tree walk over `scratch.dense[0..m]`: reduce up,
    /// broadcast down (per edge), charging `dim · 4` bytes per message.
    /// Leaves the merged f64 sums in `scratch.dense[root]` and returns the
    /// root index.
    fn dense_tree(
        &self,
        m: usize,
        dim: usize,
        ledger: &NetworkLedger,
        scratch: &mut AllReduceScratch,
    ) -> (usize, AllReduceOutcome) {
        let vec_bytes = (dim * std::mem::size_of::<f32>()) as u64;
        scratch.active.clear();
        scratch.active.extend(0..m);
        scratch.pairs_per_round.clear();
        let mut rounds = 0usize;
        let mut bytes = 0u64;
        let mut secs_total = 0f64;

        while scratch.active.len() > 1 {
            rounds += 1;
            let mut round_secs = 0f64;
            scratch.next_active.clear();
            let pairs = scratch.active.len() / 2;
            scratch.pairs_per_round.push(pairs);
            for t in 0..pairs {
                let a = scratch.active[2 * t];
                let b = scratch.active[2 * t + 1];
                let t_secs = ledger.record(&self.model, vec_bytes);
                bytes += vec_bytes;
                round_secs = round_secs.max(t_secs);
                let (lo, hi) = scratch.dense.split_at_mut(a.max(b));
                let (dst, src) = if a < b { (&mut lo[a], &hi[0]) } else { (&mut hi[0], &lo[b]) };
                for (x, y) in dst.iter_mut().zip(src.iter()) {
                    *x += *y;
                }
                scratch.next_active.push(a);
            }
            if scratch.active.len() % 2 == 1 {
                scratch.next_active.push(*scratch.active.last().unwrap());
            }
            std::mem::swap(&mut scratch.active, &mut scratch.next_active);
            secs_total += round_secs;
        }

        // broadcast: the merged vector retraces the tree, one message per
        // edge (m - 1 total), levels concurrent for the time model
        for &pairs in scratch.pairs_per_round.iter().rev() {
            let mut round_secs = 0f64;
            for _ in 0..pairs {
                let t = ledger.record(&self.model, vec_bytes);
                bytes += vec_bytes;
                round_secs = round_secs.max(t);
            }
            secs_total += round_secs;
        }

        let root = scratch.active[0];
        (root, AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total })
    }
}

/// What one off-thread merge sends back: the merged node (installed at
/// `slot`) plus the four input buffers, recycled into the spare pool.
struct MergeDone {
    slot: usize,
    idx: Vec<u32>,
    val: Vec<f64>,
    spare_a: (Vec<u32>, Vec<f64>),
    spare_b: (Vec<u32>, Vec<f64>),
}

/// The sparse exchange engine shared by every `cluster::comm` collective:
/// validate + load the f64 accumulators, then run the charged tree walk.
/// `m == 1` is a free reduction (straight copy, f32 exact).
pub(crate) fn run_sparse_exchange<'a>(
    model: &NetworkModel,
    m: usize,
    contrib: &dyn Fn(usize) -> &'a SparseVec,
    dim: usize,
    ctx: &CommCtx<'_>,
    scratch: &mut AllReduceScratch,
    out: &mut SparseVec,
) -> AllReduceOutcome {
    assert!(m > 0, "allreduce needs at least one contribution");
    for k in 0..m {
        assert_eq!(contrib(k).dim, dim, "ragged allreduce contribution");
    }
    if m == 1 {
        let c = contrib(0);
        out.clear(dim);
        out.indices.extend_from_slice(&c.indices);
        out.values.extend_from_slice(&c.values);
        return AllReduceOutcome::free();
    }
    if scratch.acc_idx.len() < m {
        scratch.acc_idx.resize_with(m, Vec::new);
        scratch.acc_val.resize_with(m, Vec::new);
    }
    for k in 0..m {
        // slots emptied by a previous walk's `take` are refilled from the
        // spare pool, so steady-state exchanges reuse the same heap blocks
        if scratch.acc_idx[k].capacity() == 0 {
            if let Some(s) = scratch.spare_idx.pop() {
                scratch.acc_idx[k] = s;
            }
            if let Some(s) = scratch.spare_val.pop() {
                scratch.acc_val[k] = s;
            }
        }
        let c = contrib(k);
        let idx = &mut scratch.acc_idx[k];
        let val = &mut scratch.acc_val[k];
        idx.clear();
        val.clear();
        idx.extend_from_slice(&c.indices);
        val.extend(c.values.iter().map(|&v| v as f64));
    }
    sparse_tree_exchange(model, m, dim, ctx, scratch, out)
}

/// The charged sparse tree walk: reduce up (merges on the executor, one
/// codec-picked message per pair), broadcast the merged root down per
/// edge. With `ctx.charge = false` the same merges run with zero wire cost
/// (the allgather-Δβ strategy's leader-local Δm recomputation).
fn sparse_tree_exchange(
    model: &NetworkModel,
    m: usize,
    dim: usize,
    ctx: &CommCtx<'_>,
    scratch: &mut AllReduceScratch,
    out: &mut SparseVec,
) -> AllReduceOutcome {
    scratch.active.clear();
    scratch.active.extend(0..m);
    scratch.pairs_per_round.clear();
    let mut rounds = 0usize;
    let mut bytes = 0u64;
    let mut secs_total = 0f64;
    let (done_tx, done_rx) = mpsc::channel::<MergeDone>();

    while scratch.active.len() > 1 {
        rounds += 1;
        // all pair-messages in a round are concurrent: charge the max, not
        // the sum, for time; bytes are summed
        let mut round_secs = 0f64;
        scratch.next_active.clear();
        let pairs = scratch.active.len() / 2;
        scratch.pairs_per_round.push(pairs);
        let mut jobs: Vec<Job> = Vec::with_capacity(pairs);
        for t in 0..pairs {
            let a = scratch.active[2 * t];
            let b = scratch.active[2 * t + 1];
            if ctx.charge {
                let (codec, cost) = ctx.policy.pick(&scratch.acc_idx[b], dim, ctx.class);
                let t_secs = ctx.ledger.record(model, cost);
                bytes += cost;
                round_secs = round_secs.max(t_secs);
                if codec == WireCodec::DeltaVarintF16 {
                    quantize_f16_f64(&mut scratch.acc_val[b]);
                }
            }
            let a_idx = std::mem::take(&mut scratch.acc_idx[a]);
            let a_val = std::mem::take(&mut scratch.acc_val[a]);
            let b_idx = std::mem::take(&mut scratch.acc_idx[b]);
            let b_val = std::mem::take(&mut scratch.acc_val[b]);
            let mut o_idx = scratch.spare_idx.pop().unwrap_or_default();
            let mut o_val = scratch.spare_val.pop().unwrap_or_default();
            let tx = done_tx.clone();
            jobs.push(Box::new(move || {
                merge_sorted_into(&a_idx, &a_val, &b_idx, &b_val, &mut o_idx, &mut o_val);
                let _ = tx.send(MergeDone {
                    slot: a,
                    idx: o_idx,
                    val: o_val,
                    spare_a: (a_idx, a_val),
                    spare_b: (b_idx, b_val),
                });
            }));
            scratch.next_active.push(a);
        }
        if scratch.active.len() % 2 == 1 {
            scratch.next_active.push(*scratch.active.last().unwrap());
        }
        ctx.exec.run_all(jobs);
        for _ in 0..pairs {
            let d = done_rx.recv().expect("tree-merge worker dropped its result");
            scratch.acc_idx[d.slot] = d.idx;
            scratch.acc_val[d.slot] = d.val;
            let (si, sv) = d.spare_a;
            scratch.spare_idx.push(si);
            scratch.spare_val.push(sv);
            let (si, sv) = d.spare_b;
            scratch.spare_idx.push(si);
            scratch.spare_val.push(sv);
        }
        std::mem::swap(&mut scratch.active, &mut scratch.next_active);
        secs_total += round_secs;
    }

    // broadcast: one message per edge, the merged root's payload each time.
    // With `ctx.broadcast = false` the exchange is a *gather*: the leader
    // keeps the merged root and no retrace happens (worker-held β shards
    // apply their own Δβ locally, so nothing travels back down) — but the
    // root codec pick still runs, because a lossy root codec quantizes the
    // values the leader will apply and ship onward.
    let root = scratch.active[0];
    if ctx.charge {
        let (codec, cost) = ctx.policy.pick(&scratch.acc_idx[root], dim, ctx.class);
        if codec == WireCodec::DeltaVarintF16 {
            quantize_f16_f64(&mut scratch.acc_val[root]);
        }
        if ctx.broadcast {
            for &pairs in scratch.pairs_per_round.iter().rev() {
                let mut round_secs = 0f64;
                for _ in 0..pairs {
                    let t = ctx.ledger.record(model, cost);
                    bytes += cost;
                    round_secs = round_secs.max(t);
                }
                secs_total += round_secs;
            }
        }
    }

    out.clear(dim);
    for (i, &v) in scratch.acc_idx[root].iter().zip(&scratch.acc_val[root]) {
        out.push(*i, v as f32);
    }
    AllReduceOutcome { rounds, bytes_moved: bytes, simulated_secs: secs_total }
}

/// Two-pointer merge of two sorted sparse accumulators into `(oi, ov)`;
/// shared indices sum in `f64` (`a + b`, the same order as the dense path).
/// `pub(crate)`: the threaded `NativeEngine` combines its per-thread Δm
/// accumulators with this exact merge so a T-threaded worker is bit-identical
/// to T single-threaded machines under the matching sub-partition.
pub(crate) fn merge_sorted_into(
    ai: &[u32],
    av: &[f64],
    bi: &[u32],
    bv: &[f64],
    oi: &mut Vec<u32>,
    ov: &mut Vec<f64>,
) {
    oi.clear();
    ov.clear();
    oi.reserve(ai.len() + bi.len());
    ov.reserve(av.len() + bv.len());
    let (mut x, mut y) = (0usize, 0usize);
    while x < ai.len() && y < bi.len() {
        match ai[x].cmp(&bi[y]) {
            std::cmp::Ordering::Less => {
                oi.push(ai[x]);
                ov.push(av[x]);
                x += 1;
            }
            std::cmp::Ordering::Greater => {
                oi.push(bi[y]);
                ov.push(bv[y]);
                y += 1;
            }
            std::cmp::Ordering::Equal => {
                oi.push(ai[x]);
                ov.push(av[x] + bv[y]);
                x += 1;
                y += 1;
            }
        }
    }
    oi.extend_from_slice(&ai[x..]);
    ov.extend_from_slice(&av[x..]);
    oi.extend_from_slice(&bi[y..]);
    ov.extend_from_slice(&bv[y..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::{Collective, TaskExecutor};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn sum_serial(contribs: &[Vec<f32>]) -> Vec<f64> {
        let mut acc = vec![0f64; contribs[0].len()];
        for c in contribs {
            for (a, &x) in acc.iter_mut().zip(c) {
                *a += x as f64;
            }
        }
        acc
    }

    #[test]
    fn allreduce_equals_serial_sum() {
        for m in [1usize, 2, 3, 5, 8, 16] {
            let contribs: Vec<Vec<f32>> = (0..m)
                .map(|k| (0..50).map(|i| ((k * 50 + i) as f32).sin()).collect())
                .collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (got, outcome) = ar.sum(&contribs, &ledger);
            let want = sum_serial(&contribs);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-4, "m={m}");
            }
            if m > 1 {
                assert_eq!(outcome.rounds, (m as f64).log2().ceil() as usize);
                assert!(outcome.bytes_moved > 0);
                // per-edge accounting: reduce + broadcast each move one
                // dim·4 message per edge, (m - 1) edges per phase
                assert_eq!(outcome.bytes_moved, 2 * (m as u64 - 1) * 50 * 4);
            }
        }
    }

    #[test]
    fn single_machine_is_free_reduction() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let (out, outcome) = ar.sum(&[vec![1.0, 2.0]], &ledger);
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(outcome.rounds, 0);
    }

    #[test]
    fn bytes_scale_log_in_machines() {
        // O((n+p) ln M): doubling M adds ~one round, not ~double bytes/machine
        let n = 10_000usize;
        let cost = |m: usize| {
            let contribs: Vec<Vec<f32>> = (0..m).map(|_| vec![1f32; n]).collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());
            let ledger = NetworkLedger::new();
            let (_, o) = ar.sum(&contribs, &ledger);
            o.simulated_secs
        };
        let t4 = cost(4);
        let t16 = cost(16);
        // log2(16)/log2(4) = 2: simulated time should grow ~2x, not 4x
        assert!(t16 / t4 < 2.6, "t4={t4} t16={t16}");
        assert!(t16 > t4);
    }

    #[test]
    fn dense_scratch_path_matches_sum_wrapper() {
        // the baselines' allocation-free call path: identical sums, bytes
        // and rounds to the compat wrapper, stable across scratch reuse
        let contribs: Vec<Vec<f32>> = (0..5)
            .map(|k| (0..40).map(|i| ((k * 40 + i) as f32).cos()).collect())
            .collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let (want, o_want) = ar.sum(&contribs, &NetworkLedger::new());
        let mut scratch = AllReduceScratch::default();
        let mut out = Vec::new();
        for _ in 0..2 {
            let ledger = NetworkLedger::new();
            let o = ar.sum_dense_into(&contribs, &ledger, &mut scratch, &mut out);
            assert_eq!(out, want);
            assert_eq!(o.bytes_moved, o_want.bytes_moved);
            assert_eq!(o.rounds, o_want.rounds);
            assert_eq!(ledger.total_bytes(), o.bytes_moved);
        }
        // single machine stays a free reduction
        let one = vec![vec![1.5f32, -2.0]];
        let o = ar.sum_dense_into(&one, &NetworkLedger::new(), &mut scratch, &mut out);
        assert_eq!(out, vec![1.5, -2.0]);
        assert_eq!(o.rounds, 0);
        assert_eq!(o.bytes_moved, 0);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_contributions_panic() {
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        ar.sum(&[vec![1.0], vec![1.0, 2.0]], &ledger);
    }

    fn sparse_of(dense: &[f32]) -> SparseVec {
        SparseVec::from_dense(dense)
    }

    #[test]
    fn sparse_sum_matches_dense_sum_exactly() {
        // three ragged-sparsity contributions over dim = 12, incl. overlap
        let dense: Vec<Vec<f32>> = vec![
            vec![0., 1., 0., 0., 2., 0., 0., 0., 0., 0., -1., 0.],
            vec![0., 0., 0., 0., 3., 0., 0.5, 0., 0., 0., 0., 0.],
            vec![4., 0., 0., 0., 0., 0., 0., 0., 0., 0., 1., 0.],
        ];
        let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(12);
        let o = ar.sum_sparse_into(sparse.iter(), 12, &ledger, &mut scratch, &mut out);
        let (dense_out, _) = ar.sum(&dense, &NetworkLedger::new());
        assert_eq!(out.to_dense(), dense_out);
        assert!(o.bytes_moved > 0);
        assert_eq!(o.rounds, 2);
    }

    #[test]
    fn sparse_wire_charges_payload_not_dim() {
        // two contributions with 2 nnz each over a huge dim: the reduce edge
        // carries 2 entries (16 bytes) and the one broadcast edge the
        // merged 4 (32 bytes)
        let a = {
            let mut v = SparseVec::new(1_000_000);
            v.push(10, 1.0);
            v.push(20, 2.0);
            v
        };
        let b = {
            let mut v = SparseVec::new(1_000_000);
            v.push(15, 3.0);
            v.push(25, 4.0);
            v
        };
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o =
            ar.sum_sparse_into([&a, &b].into_iter(), 1_000_000, &ledger, &mut scratch, &mut out);
        assert_eq!(o.bytes_moved, 16 + 32);
        assert_eq!(out.nnz(), 4);
        assert_eq!(ledger.total_bytes(), o.bytes_moved);
    }

    #[test]
    fn per_message_cost_model_picks_cheapest_wire() {
        // 30-nnz reduce message over dim = 100: sparse (240) beats dense
        // (400); the merged 60-nnz broadcast payload flips to dense (400 <
        // 480). The old whole-tree 0.25 density fallback would have charged
        // 800 — the per-message model charges 640.
        let dim = 100usize;
        let a = sparse_of(&(0..dim).map(|i| if i < 30 { 1.0 } else { 0.0 }).collect::<Vec<_>>());
        let b = sparse_of(&(0..dim).map(|i| if i >= 70 { 2.0 } else { 0.0 }).collect::<Vec<_>>());
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o = ar.sum_sparse_into([&a, &b].into_iter(), dim, &ledger, &mut scratch, &mut out);
        assert_eq!(o.bytes_moved, 240 + 400);
        assert_eq!(out.nnz(), 60);
    }

    #[test]
    fn broadcast_charges_per_edge_not_per_round() {
        // M = 4, one distinct entry per machine: reduce edges move 8, 8 and
        // 16 bytes; the 4-entry root then crosses all M - 1 = 3 broadcast
        // edges (the seed's per-round model would have charged only 2)
        let contribs: Vec<SparseVec> = (0..4)
            .map(|k| {
                let mut v = SparseVec::new(1_000);
                v.push(k as u32, (k + 1) as f32);
                v
            })
            .collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o = ar.sum_sparse_into(contribs.iter(), 1_000, &ledger, &mut scratch, &mut out);
        assert_eq!(o.rounds, 2);
        assert_eq!(o.bytes_moved, 8 + 8 + 16 + 3 * 32);
        assert_eq!(out.nnz(), 4);
    }

    #[test]
    fn all_zero_contributions_cost_nothing_on_the_wire() {
        let contribs: Vec<SparseVec> = (0..4).map(|_| SparseVec::new(50)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let ledger = NetworkLedger::new();
        let mut scratch = AllReduceScratch::default();
        let mut out = SparseVec::new(0);
        let o = ar.sum_sparse_into(contribs.iter(), 50, &ledger, &mut scratch, &mut out);
        assert_eq!(o.bytes_moved, 0);
        assert_eq!(out.nnz(), 0);
        assert_eq!(out.dim, 50);
    }

    #[test]
    fn scratch_reuse_is_stable_across_calls() {
        // same reduction twice through one scratch must give identical
        // results and identical ledger charges (buffers fully reset)
        let dense: Vec<Vec<f32>> = (0..5)
            .map(|k| {
                (0..400).map(|i| if (i + k) % 37 == 0 { (k + i) as f32 } else { 0.0 }).collect()
            })
            .collect();
        let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let mut scratch = AllReduceScratch::default();
        let mut out1 = SparseVec::new(0);
        let mut out2 = SparseVec::new(0);
        let l1 = NetworkLedger::new();
        let o1 = ar.sum_sparse_into(sparse.iter(), 400, &l1, &mut scratch, &mut out1);
        let l2 = NetworkLedger::new();
        let o2 = ar.sum_sparse_into(sparse.iter(), 400, &l2, &mut scratch, &mut out2);
        assert_eq!(out1, out2);
        assert_eq!(o1.bytes_moved, o2.bytes_moved);
        let want = sum_serial(&dense);
        let got = out1.to_dense();
        for i in 0..400 {
            assert!((got[i] as f64 - want[i]).abs() < 1e-5, "i = {i}");
        }
    }

    /// Counts jobs and runs them inline — proves the merges go through the
    /// executor (one job per tree edge) without changing the result.
    struct CountingExec(AtomicUsize);

    impl TaskExecutor for CountingExec {
        fn run_all(&self, jobs: Vec<Job>) {
            self.0.fetch_add(jobs.len(), Ordering::Relaxed);
            for job in jobs {
                job();
            }
        }
    }

    #[test]
    fn every_tree_merge_runs_through_the_executor() {
        for m in [2usize, 5, 8] {
            let dense: Vec<Vec<f32>> = (0..m)
                .map(|k| {
                    (0..200)
                        .map(|i| if (i + 3 * k) % 11 == 0 { (i + k) as f32 } else { 0.0 })
                        .collect()
                })
                .collect();
            let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
            let refs: Vec<&SparseVec> = sparse.iter().collect();
            let ar = TreeAllReduce::new(NetworkModel::gigabit());

            let serial_ledger = NetworkLedger::new();
            let mut scratch = AllReduceScratch::default();
            let mut want = SparseVec::new(0);
            ar.sum_sparse_into(sparse.iter(), 200, &serial_ledger, &mut scratch, &mut want);

            let counting = CountingExec(AtomicUsize::new(0));
            let ledger = NetworkLedger::new();
            let ctx = CommCtx {
                ledger: &ledger,
                policy: CodecPolicy::lossless(),
                class: MessageClass::Margins,
                exec: &counting,
                charge: true,
                broadcast: true,
            };
            let mut out = SparseVec::new(0);
            let o = ar.exchange(m, &|k| refs[k], 200, &ctx, &mut scratch, &mut out);
            assert_eq!(counting.0.load(Ordering::Relaxed), m - 1, "one merge per edge");
            assert_eq!(out, want, "executor must not change the math");
            assert_eq!(o.bytes_moved, serial_ledger.total_bytes());
        }
    }

    #[test]
    fn uncharged_exchange_moves_no_bytes_but_merges_identically() {
        // charge = false models the allgather-Δβ strategy's leader-local
        // Δm recomputation: same deterministic merge, zero wire traffic
        let dense: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..60).map(|i| if (i + k) % 7 == 0 { i as f32 + 0.5 } else { 0.0 }).collect())
            .collect();
        let sparse: Vec<SparseVec> = dense.iter().map(|d| sparse_of(d)).collect();
        let refs: Vec<&SparseVec> = sparse.iter().collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let mut scratch = AllReduceScratch::default();
        let mut want = SparseVec::new(0);
        ar.sum_sparse_into(sparse.iter(), 60, &NetworkLedger::new(), &mut scratch, &mut want);

        let ledger = NetworkLedger::new();
        let ctx = CommCtx {
            ledger: &ledger,
            policy: CodecPolicy::lossless(),
            class: MessageClass::Margins,
            exec: &SerialExecutor,
            charge: false,
            broadcast: false,
        };
        let mut out = SparseVec::new(0);
        let o = ar.exchange(4, &|k| refs[k], 60, &ctx, &mut scratch, &mut out);
        assert_eq!(out, want);
        assert_eq!(o.bytes_moved, 0);
        assert_eq!(ledger.total_bytes(), 0);
        assert_eq!(o.simulated_secs, 0.0);
    }

    #[test]
    fn gather_charges_reduce_edges_only() {
        // the accounting change behind worker-held β shards (PR 4): with
        // `broadcast = false` the exchange is a gather-to-leader — same
        // deterministic merge, but the (M - 1) · root broadcast retrace of
        // the PR-3 model is gone. Disjoint 2-nnz contributions from M = 4
        // machines: reduce edges move 16 + 16 + 32 bytes; the full
        // allreduce added 3 broadcast edges of the 8-entry root (64 bytes
        // each).
        let contribs: Vec<SparseVec> = (0..4)
            .map(|k| {
                let mut v = SparseVec::new(100_000);
                v.push(10 * k as u32, 1.0);
                v.push(10 * k as u32 + 5, 2.0);
                v
            })
            .collect();
        let refs: Vec<&SparseVec> = contribs.iter().collect();
        let ar = TreeAllReduce::new(NetworkModel::gigabit());
        let run = |broadcast: bool| {
            let ledger = NetworkLedger::new();
            let mut scratch = AllReduceScratch::default();
            let mut out = SparseVec::new(0);
            let ctx = CommCtx {
                ledger: &ledger,
                policy: CodecPolicy::lossless(),
                class: MessageClass::Beta,
                exec: &SerialExecutor,
                charge: true,
                broadcast,
            };
            let o = ar.exchange(4, &|k| refs[k], 100_000, &ctx, &mut scratch, &mut out);
            (out, o.bytes_moved, o.simulated_secs)
        };
        let (full_out, full_bytes, full_secs) = run(true);
        let (gather_out, gather_bytes, gather_secs) = run(false);
        assert_eq!(full_out, gather_out, "gather must not change the merge");
        assert_eq!(gather_bytes, 16 + 16 + 32);
        assert_eq!(full_bytes, gather_bytes + 3 * 64);
        assert!(gather_secs < full_secs);
    }
}
