//! Cluster substrate: feature partitioners, a byte-accounted network model
//! (Gigabit-Ethernet-like, the paper's testbed), and the node protocol
//! every leader ↔ worker interaction routes through.
//!
//! The stack has four layers, bottom up:
//!
//! * [`codec`] — **wire formats.** Three codecs (dense `f32`, sparse
//!   `u32 + f32`, delta-varint index + `f16` value) selected **per
//!   message** by a byte-cost model ([`codec::CodecPolicy::pick`]); the
//!   lossy f16 codec is opt-in per message class and never touches
//!   β-carrying messages by default.
//! * [`transport`] + [`protocol`] — **how messages travel.** The
//!   [`transport::Transport`] trait is an ordered, reliable
//!   [`protocol::NodeMessage`] stream with two implementations: in-process
//!   channels (worker threads, no serialization, owned buffers transfer)
//!   and a real multi-process TCP byte stream whose frames encode sparse
//!   payloads with the layer-1 codecs — so the bytes a socket writes for a
//!   Δ-payload are exactly the bytes the ledger's cost functions charge.
//!   **Failure model:** peer death, malformed frames, and (under a
//!   configured `recv_timeout_secs`) wedged peers all surface as clean,
//!   attributable errors — never hangs. With `supervise = true` the
//!   leader goes further: it probes every link with `Ping` heartbeats,
//!   rolls the fit back to the last in-memory recovery checkpoint,
//!   re-admits a replacement for each dead worker (validated against the
//!   shard identity it must hold), and resumes — the recovered fit is
//!   bit-identical to an undisturbed run, with the supervisor's own
//!   traffic kept in a separate recovery ledger bucket. The
//!   [`transport::FaultyTransport`] wrapper injects deterministic faults
//!   ([`transport::Fault`]: drop, delay, truncate, corrupt) on the n-th
//!   recv for testing every one of those paths.
//! * [`comm`] + [`allreduce`] — **collectives.** The [`comm::Collective`]
//!   trait over the simulated network ([`TreeAllReduce`], [`comm::AllGather`])
//!   shares one deterministic pairwise-f64 tree engine: per-message codec
//!   charging on reduce edges, per-edge broadcast accounting (`M - 1`
//!   messages, levels concurrent in time), and a gather mode
//!   (`CommCtx::broadcast = false`) that drops the broadcast term for
//!   flows the nodes no longer consume. Tree-node merges run on a
//!   [`comm::TaskExecutor`] (the solver plugs its `WorkerPool` in), and
//!   [`comm::TreeByteEstimator`] — an EWMA-sharpened dry-walk cost model —
//!   drives the automatic reduce-Δm vs allgather-Δβ strategy pick.
//!
//!   **Topology matrix.** The merge *bracket* — ascending machine ids,
//!   pairwise rounds, survivor in the lower slot — is fixed; what varies
//!   is where its edges physically run ([`comm::bracket_children`] /
//!   [`comm::bracket_parent`] derive the forest both sides use):
//!
//!   | transport   | `topology = star` (default)     | `topology = tree`              |
//!   |-------------|---------------------------------|--------------------------------|
//!   | in-process  | leader-staged merges            | leader-staged merges           |
//!   | socket      | leader-staged merges            | **peer-to-peer tree merges**   |
//!
//!   Leader-staged: every worker ships its raw contribution to the
//!   leader, which runs the bracket on its task pool and *simulates* the
//!   per-edge byte charges. Peer-to-peer: workers open direct
//!   worker↔worker links (epoch-fenced, shard-identity-validated — see
//!   [`transport::PeerTable`]), each folds its bracket children's payloads
//!   through the same pairwise-f64 merge and forwards one message to its
//!   parent, so the leader's data-plane traffic per iteration is O(1) in
//!   M: one `Sweep` down and one pre-merged `TreeSwept` up on the root
//!   edge ([`protocol::TreeSwept`] carries per-origin/per-edge nnz
//!   metadata; [`comm::replay_tree_charges`] replays the identical ledger
//!   charges from it).
//!
//!   **Bit-identity pins.** All four cells produce bit-identical fits —
//!   objective trajectory, β bits, and the charged comm ledger: the merge
//!   order is the same bracket, interior tree edges carry exact f64
//!   intermediates (f32-framed only when every value round-trips —
//!   [`protocol::TreePayload`]), and machine 0 applies the bracket root's
//!   f32 rounding at exactly the point the staged engine does. Pinned in
//!   `tests/wire_codec.rs` (tree vs star vs in-process trajectories,
//!   measured-vs-charged bytes per edge) and `tests/failover.rs`
//!   (supervised recovery under both topologies).
//! * [`node`] — **stateful endpoints.** A [`node::WorkerNode`] owns its
//!   feature shard, its engine, **its β shard, and its margins copy**: a
//!   `Sweep` request carries only `(λ, ν)` (the node derives `(w, z)` from
//!   its own margins), and an `Apply` carries only `(α, Δm)` — the node
//!   applies `α·Δβ_local` from its own sweep output, so no per-sweep
//!   `beta_local` gather or merged-Δβ broadcast exists anywhere in the
//!   system. Leader-held and worker-held state stay bit-identical (the
//!   checkpoint pull verifies it). Nodes self-load their shards from the
//!   on-disk store ([`node::WorkerNode::from_store`]) and additionally
//!   serve the out-of-core leader's one-shot setup reductions: `LambdaMax`
//!   (per-shard λ_max contribution) and `Margins` (per-shard Σβ_jx_ij for
//!   warmstart installs).
//!
//! **Accounting contract.** The `comm_bytes` ledger charges the collective
//! Δ-exchanges per tree edge — reduce messages always; broadcast retraces
//! only for flows a node actually consumes (the merged Δm under reduce-Δm).
//! Handshake, sweep-request, apply, state-sync, and one-shot setup frames
//! (λ_max / warmstart-margins reductions) are not charged: they are
//! O(1)-per-iteration (or per-fit) control traffic or model the
//! shared-state bookkeeping the paper's cost analysis excludes, and the allgather-Δβ
//! strategy's leader-side Δm recombination remains an uncharged local
//! computation exactly as in PR 3. Under the default lossless policy,
//! what *is* charged agrees byte-for-byte with what a
//! [`transport::SocketTransport`] would serialize for the same payload,
//! because both call the same codec cost functions (the opt-in lossy
//! `wire_f16_*` knobs charge the f16 cost while the frames stay
//! losslessly encoded — see [`protocol`]).
//!
//! The algorithmic content of d-GLMNET is independent of where the workers
//! run; the network model exists so the communication-cost claims of §3
//! are *measured* (bytes, rounds, simulated seconds) rather than asserted.

pub mod allreduce;
pub mod codec;
pub mod comm;
pub mod network;
pub mod node;
pub mod partition;
pub mod protocol;
pub mod transport;

pub use allreduce::TreeAllReduce;
pub use codec::{CodecPolicy, MessageClass, WireCodec};
pub use comm::{
    bracket_children, bracket_parent, replay_tree_charges, AllGather, ByteEstimate,
    Collective, SerialExecutor, TaskExecutor, TreeByteEstimator,
};
pub use network::{NetworkLedger, NetworkModel};
pub use node::WorkerNode;
pub use partition::{FeaturePartition, PartitionStrategy};
pub use protocol::{EdgeStat, NodeMessage, OriginStat, PeerInfo, Topology, TreeSwept};
pub use transport::{
    Fault, FaultyTransport, PeerTable, SocketTransport, Transport, WireCounters,
};
