//! Simulated cluster substrate: feature partitioners, a byte-accounted
//! network model (Gigabit-Ethernet-like, the paper's testbed), and the tree
//! AllReduce of Alg 4 step 3 whose simulated cost is `O((n+p)·ln M)`.
//!
//! The algorithmic content of d-GLMNET is unchanged by running workers as
//! in-process threads; the network model exists so the communication-cost
//! claims of §3 are *measured* (bytes, rounds, simulated seconds) rather
//! than asserted.

pub mod allreduce;
pub mod network;
pub mod partition;

pub use allreduce::TreeAllReduce;
pub use network::{NetworkModel, NetworkLedger};
pub use partition::{FeaturePartition, PartitionStrategy};
