//! Simulated cluster substrate: feature partitioners, a byte-accounted
//! network model (Gigabit-Ethernet-like, the paper's testbed), and the
//! pluggable communication subsystem every Δ-exchange routes through.
//!
//! The comm stack has three layers:
//!
//! * [`codec`] — wire formats. Three codecs (dense `f32`, sparse
//!   `u32 + f32`, delta-varint index + `f16` value) selected **per
//!   message** by a byte-cost model ([`codec::CodecPolicy::pick`]); the
//!   lossy f16 codec is opt-in per message class and never touches
//!   β-carrying messages by default.
//! * [`comm`] — the [`comm::Collective`] trait over the simulated network
//!   ([`TreeAllReduce`] and [`comm::AllGather`]), the [`comm::TaskExecutor`]
//!   that moves tree-node merges off the leader thread (the solver plugs
//!   its `WorkerPool` in), and the byte estimator behind the automatic
//!   reduce-Δm vs allgather-Δβ strategy choice.
//! * [`allreduce`] — the shared binary-tree engine: deterministic pairwise
//!   `f64` merges, per-message codec charging on reduce edges, per-edge
//!   broadcast accounting (`M - 1` messages, levels concurrent in time).
//!
//! The algorithmic content of d-GLMNET is unchanged by running workers as
//! in-process threads; the network model exists so the communication-cost
//! claims of §3 are *measured* (bytes, rounds, simulated seconds) rather
//! than asserted.

pub mod allreduce;
pub mod codec;
pub mod comm;
pub mod network;
pub mod partition;

pub use allreduce::TreeAllReduce;
pub use codec::{CodecPolicy, MessageClass, WireCodec};
pub use comm::{AllGather, Collective, SerialExecutor, TaskExecutor};
pub use network::{NetworkLedger, NetworkModel};
pub use partition::{FeaturePartition, PartitionStrategy};
