//! Feature partitioning: split {1..p} into M disjoint sets S_1..S_M
//! (paper §2). Strategies: round-robin, contiguous ranges, and greedy
//! nnz-balanced (equalizes per-machine work, which is O(nnz of the shard)).

/// How features are assigned to machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// feature j -> machine j mod M.
    RoundRobin,
    /// M near-equal contiguous ranges.
    Contiguous,
    /// Greedy balance by per-feature nnz (requires column counts).
    NnzBalanced,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round-robin" | "roundrobin" | "rr" => Some(Self::RoundRobin),
            "contiguous" | "range" => Some(Self::Contiguous),
            "nnz-balanced" | "nnz" | "balanced" => Some(Self::NnzBalanced),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`PartitionStrategy::parse`];
    /// recorded in shard-store manifests).
    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::Contiguous => "contiguous",
            Self::NnzBalanced => "nnz-balanced",
        }
    }
}

/// A concrete disjoint cover of the feature space.
#[derive(Debug, Clone)]
pub struct FeaturePartition {
    /// feature -> machine
    assignment: Vec<u32>,
    machines: usize,
}

impl FeaturePartition {
    /// Build a partition of `p` features over `m` machines. `col_nnz` is
    /// required by [`PartitionStrategy::NnzBalanced`] (ignored otherwise).
    pub fn build(
        strategy: PartitionStrategy,
        p: usize,
        m: usize,
        col_nnz: Option<&[usize]>,
    ) -> Self {
        assert!(m >= 1, "need at least one machine");
        let mut assignment = vec![0u32; p];
        match strategy {
            PartitionStrategy::RoundRobin => {
                for (j, a) in assignment.iter_mut().enumerate() {
                    *a = (j % m) as u32;
                }
            }
            PartitionStrategy::Contiguous => {
                // ceil-sized ranges; the last machines may be one shorter
                for (j, a) in assignment.iter_mut().enumerate() {
                    *a = ((j * m) / p.max(1)).min(m - 1) as u32;
                }
            }
            PartitionStrategy::NnzBalanced => {
                let counts = col_nnz.expect("NnzBalanced requires column nnz counts");
                assert_eq!(counts.len(), p);
                // greedy: heaviest feature first onto the lightest machine
                let mut order: Vec<usize> = (0..p).collect();
                order.sort_by_key(|&j| std::cmp::Reverse(counts[j]));
                let mut load = vec![0usize; m];
                for j in order {
                    let k = (0..m).min_by_key(|&k| (load[k], k)).unwrap();
                    assignment[j] = k as u32;
                    load[k] += counts[j].max(1);
                }
            }
        }
        Self { assignment, machines: m }
    }

    /// Rebuild a partition from per-machine global-column lists (the shard
    /// store's on-disk identity). Validates that the lists are a disjoint
    /// cover of `0..p` — a store whose shards overlap or leave a feature
    /// unowned is corrupt and must not reach the solver.
    pub fn from_feature_lists(
        lists: &[Vec<u32>],
        p: usize,
    ) -> crate::error::Result<Self> {
        use crate::error::DlrError;
        let mut assignment = vec![u32::MAX; p];
        for (k, cols) in lists.iter().enumerate() {
            for &c in cols {
                let j = c as usize;
                if j >= p {
                    return Err(DlrError::Data(format!(
                        "shard {k} claims feature {j} but p = {p}"
                    )));
                }
                if assignment[j] != u32::MAX {
                    return Err(DlrError::Data(format!(
                        "feature {j} is owned by both machine {} and machine {k}",
                        assignment[j]
                    )));
                }
                assignment[j] = k as u32;
            }
        }
        if let Some(j) = assignment.iter().position(|&a| a == u32::MAX) {
            return Err(DlrError::Data(format!(
                "feature {j} is owned by no shard — the store does not cover the \
                 feature space"
            )));
        }
        Ok(Self { assignment, machines: lists.len() })
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    pub fn n_features(&self) -> usize {
        self.assignment.len()
    }

    #[inline]
    pub fn machine_of(&self, feature: usize) -> usize {
        self.assignment[feature] as usize
    }

    /// Global feature ids owned by machine `k`, ascending.
    pub fn features_of(&self, k: usize) -> Vec<u32> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a as usize == k)
            .map(|(j, _)| j as u32)
            .collect()
    }

    /// Per-machine shard sizes.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.machines];
        for &a in &self.assignment {
            s[a as usize] += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_disjoint_cover(p: &FeaturePartition) {
        let mut seen = vec![false; p.n_features()];
        for k in 0..p.machines() {
            for f in p.features_of(k) {
                assert!(!seen[f as usize], "feature {f} assigned twice");
                seen[f as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some feature unassigned");
    }

    #[test]
    fn round_robin_cover_and_balance() {
        let p = FeaturePartition::build(PartitionStrategy::RoundRobin, 103, 4, None);
        is_disjoint_cover(&p);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn contiguous_is_monotone() {
        let p = FeaturePartition::build(PartitionStrategy::Contiguous, 100, 3, None);
        is_disjoint_cover(&p);
        let mut last = 0;
        for j in 0..100 {
            assert!(p.machine_of(j) >= last);
            last = p.machine_of(j);
        }
    }

    #[test]
    fn nnz_balanced_beats_contiguous_on_skew() {
        // heavily skewed column counts: first 10 columns hold most nnz
        let mut counts = vec![1usize; 100];
        for c in counts.iter_mut().take(10) {
            *c = 1000;
        }
        let bal = FeaturePartition::build(PartitionStrategy::NnzBalanced, 100, 5, Some(&counts));
        is_disjoint_cover(&bal);
        let load = |p: &FeaturePartition| -> Vec<usize> {
            (0..5)
                .map(|k| p.features_of(k).iter().map(|&f| counts[f as usize]).sum())
                .collect()
        };
        let bal_load = load(&bal);
        let spread = bal_load.iter().max().unwrap() - bal_load.iter().min().unwrap();
        assert!(spread <= 100, "balanced spread too big: {bal_load:?}");

        let con = FeaturePartition::build(PartitionStrategy::Contiguous, 100, 5, None);
        let con_load = load(&con);
        let con_spread = con_load.iter().max().unwrap() - con_load.iter().min().unwrap();
        assert!(spread < con_spread, "{bal_load:?} vs {con_load:?}");
    }

    #[test]
    fn single_machine_owns_everything() {
        let p = FeaturePartition::build(PartitionStrategy::RoundRobin, 17, 1, None);
        assert_eq!(p.features_of(0).len(), 17);
    }

    #[test]
    fn from_feature_lists_round_trips_and_validates() {
        let built = FeaturePartition::build(PartitionStrategy::RoundRobin, 10, 3, None);
        let lists: Vec<Vec<u32>> = (0..3).map(|k| built.features_of(k)).collect();
        let back = FeaturePartition::from_feature_lists(&lists, 10).unwrap();
        for j in 0..10 {
            assert_eq!(back.machine_of(j), built.machine_of(j));
        }
        // overlap, gap, and out-of-range claims are rejected
        assert!(FeaturePartition::from_feature_lists(&[vec![0, 1], vec![1]], 2).is_err());
        assert!(FeaturePartition::from_feature_lists(&[vec![0], vec![2]], 3).is_err());
        assert!(FeaturePartition::from_feature_lists(&[vec![0], vec![5]], 2).is_err());
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(PartitionStrategy::parse("rr"), Some(PartitionStrategy::RoundRobin));
        assert_eq!(PartitionStrategy::parse("nnz"), Some(PartitionStrategy::NnzBalanced));
        assert_eq!(PartitionStrategy::parse("bogus"), None);
    }
}
