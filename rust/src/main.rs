//! `dglmnet` — the d-GLMNET launcher: dataset generation, the by-feature
//! transform, single-λ training, the full regularization path, the online
//! baseline, quick evaluation, offline scoring, and the HTTP model server.
//! The benchmark harnesses that regenerate the paper's tables/figures live
//! under `cargo bench`.

use std::io::Write;
use std::process::ExitCode;
use std::time::Duration;

use dglmnet::baselines::grid::online_grid_search;
use dglmnet::baselines::{
    DistributedOnlineEstimator, ShotgunEstimator, TruncatedGradientEstimator,
};
use dglmnet::cli::{App, CommandSpec, ParsedArgs};
use dglmnet::cluster::partition::PartitionStrategy;
use dglmnet::cluster::transport::{PeerTable, SocketTransport};
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{
    EngineKind, ExchangeStrategy, PathConfig, TopologyKind, TrainConfig, TransportKind,
};
use dglmnet::data::shuffle::shuffle_to_store;
use dglmnet::data::store::ShardStore;
use dglmnet::data::{dataset::Dataset, libsvm, synth};
use dglmnet::error::{DlrError, Result};
use dglmnet::family::FamilyKind;
use dglmnet::metrics;
use dglmnet::report::Table;
use dglmnet::solver::{
    fit_cold, Checkpoint, DGlmnetSolver, Estimator, FitResult, NoopObserver, RegPath,
    SparseModel, StepOutcome,
};

fn app() -> App {
    App::new("dglmnet", "distributed coordinate descent for L1-regularized logistic regression (Trofimov & Genkin, 2014)")
        .command(
            CommandSpec::new("gen-data", "generate a synthetic dataset (epsilon/webspam/dna shape signatures)")
                .opt("kind", "epsilon | webspam | dna", Some("dna"))
                .opt("examples", "number of examples", Some("10000"))
                .opt("features", "number of features", Some("400"))
                .opt("nnz-per-row", "non-zeros per row (sparse kinds)", Some("12"))
                .opt("seed", "rng seed", Some("1"))
                .opt("out", "output libsvm path", Some("data.svm"))
                .flag("summary", "print the Table-2 style summary only"),
        )
        .command(
            CommandSpec::new("transform", "by-example libsvm -> the paper's Table-1 by-feature format")
                .opt("input", "input libsvm path", None)
                .opt("out", "output by-feature path", Some("data.byfeature")),
        )
        .command(
            CommandSpec::new("shard", "write a sharded on-disk store (per-machine by-feature shard files + manifest) for out-of-core training")
                .opt("input", "libsvm path (omit to use --kind synthetic data)", None)
                .opt("kind", "synthetic kind when no --input", Some("dna"))
                .opt("examples", "synthetic examples", Some("10000"))
                .opt("features", "synthetic features", Some("400"))
                .opt("nnz-per-row", "non-zeros per row (sparse kinds)", Some("12"))
                .opt("seed", "rng seed (drives --train-frac splitting too)", Some("1"))
                .opt("train-frac", "shard only this train fraction (same split as `train`; 1.0 keeps everything)", Some("1.0"))
                .opt("machines", "worker shard count M", Some("4"))
                .opt("workers", "alias for --machines", None)
                .opt("partition", "round-robin | contiguous | nnz-balanced", Some("round-robin"))
                .opt("out", "store directory", Some("store"))
                .flag("in-memory", "build shards from an in-memory CSC instead of the external spill shuffle"),
        )
        .command(
            CommandSpec::new("train", "train at one lambda on a libsvm file, synthetic data, or a sharded store")
                .opt("store", "sharded store directory (out-of-core: workers self-load shards, leader stays O(n))", None)
                .opt("input", "libsvm path (omit to use --kind synthetic data)", None)
                .opt("kind", "synthetic kind when no --input", Some("dna"))
                .opt("examples", "synthetic examples", Some("10000"))
                .opt("features", "synthetic features", Some("400"))
                .opt("nnz-per-row", "non-zeros per row (sparse kinds)", Some("12"))
                .opt("solver", "dglmnet | shotgun | truncgrad | online", Some("dglmnet"))
                .opt("lambda", "L1 strength (objective scale)", Some("1.0"))
                .opt("family", "GLM family: logistic | gaussian | poisson (dglmnet)", Some("logistic"))
                .opt("alpha", "elastic-net mix in (0, 1]: 1 = pure L1 (dglmnet)", Some("1.0"))
                .opt("machines", "simulated machines M", Some("4"))
                .opt("engine", "auto | xla | native", Some("auto"))
                .opt("sweep-threads", "CD sweep threads per worker (0 = auto: host parallelism)", Some("1"))
                .flag("naive-sweep", "use the exact naive sweep kernel instead of the covariance-update one")
                .opt("max-iter", "iteration cap", Some("100"))
                .opt("tol", "relative-decrease tolerance", Some("1e-5"))
                .opt("exchange", "auto | reduce-dm | allgather-beta", Some("auto"))
                .opt("workers", "alias for --machines (worker node count)", None)
                .opt("transport", "in-process | socket", Some("in-process"))
                .opt("listen", "leader bind address for --transport socket", Some("127.0.0.1:4801"))
                .opt("topology", "star | tree — collective routing for --transport socket (tree: peer-to-peer merges, O(1) leader bandwidth)", Some("star"))
                .flag("supervise", "detect dead workers mid-fit, roll back to the last recovery checkpoint, and re-admit replacements")
                .opt("heartbeat-timeout-secs", "per-link Ping deadline when probing workers", Some("5"))
                .opt("recv-timeout-secs", "socket recv deadline in seconds (0 = wait forever)", Some("0"))
                .opt("recovery-checkpoint-every", "refresh the in-memory recovery checkpoint every k iterations", Some("1"))
                .flag("wire-f16", "allow the lossy f16 wire codec for Δ-margin messages")
                .opt("passes", "online/truncgrad passes", Some("10"))
                .opt("rounds", "shotgun rounds", Some("200"))
                .opt("parallelism", "shotgun parallel updates P", Some("8"))
                .opt("learning-rate", "online/truncgrad learning rate", Some("0.3"))
                .opt("decay", "online/truncgrad per-pass decay", Some("0.7"))
                .opt("max-secs", "wall-clock budget (dglmnet)", None)
                .opt("max-comm-bytes", "simulated comm budget (dglmnet)", None)
                .opt("budget-iters", "hard iteration budget (dglmnet)", None)
                .opt("checkpoint-out", "save a resumable checkpoint here (dglmnet)", None)
                .opt("checkpoint-every", "checkpoint every k iterations", Some("10"))
                .opt("resume", "resume a dglmnet fit from this checkpoint", None)
                .opt("seed", "rng seed", Some("1"))
                .opt("model-out", "save fitted model here", None)
                .flag("verbose", "per-iteration log"),
        )
        .command(
            CommandSpec::new("path", "regularization path (Algorithm 5) with test-set scoring")
                .opt("input", "libsvm path (omit for synthetic)", None)
                .opt("kind", "synthetic kind when no --input", Some("dna"))
                .opt("examples", "synthetic examples", Some("10000"))
                .opt("features", "synthetic features", Some("400"))
                .opt("nnz-per-row", "non-zeros per row (sparse kinds)", Some("12"))
                .opt("steps", "lambda halvings", Some("20"))
                .opt("family", "GLM family: logistic | gaussian | poisson", Some("logistic"))
                .opt("alpha", "elastic-net mix in (0, 1]: 1 = pure L1", Some("1.0"))
                .opt("machines", "simulated machines M", Some("4"))
                .opt("engine", "auto | xla | native", Some("auto"))
                .opt("max-iter", "per-lambda iteration cap", Some("50"))
                .opt("tol", "relative-decrease tolerance", Some("1e-5"))
                .opt("seed", "rng seed", Some("1"))
                .opt("csv-out", "write (series,nnz,auprc) csv here", None),
        )
        .command(
            CommandSpec::new("worker", "run one remote worker node and serve the leader over TCP")
                .opt("connect", "leader address (host:port) to join", None)
                .opt("machine", "this worker's machine index (0-based)", None)
                .opt("store", "sharded store directory — load only this machine's shard file", None)
                .opt("input", "libsvm path — must match the leader's data flags exactly", None)
                .opt("kind", "synthetic kind when no --input", Some("dna"))
                .opt("examples", "synthetic examples", Some("10000"))
                .opt("features", "synthetic features", Some("400"))
                .opt("nnz-per-row", "non-zeros per row (sparse kinds)", Some("12"))
                .opt("seed", "rng seed (drives the train/test split too)", Some("1"))
                .opt("machines", "cluster size M (must match the leader)", Some("4"))
                .opt("workers", "alias for --machines", None)
                .opt("family", "GLM family (must match the leader)", Some("logistic"))
                .opt("alpha", "elastic-net mix (must match the leader)", Some("1.0"))
                .opt("engine", "auto | xla | native", Some("auto"))
                .opt("sweep-threads", "CD sweep threads (0 = auto: host parallelism)", Some("1"))
                .flag("naive-sweep", "use the exact naive sweep kernel instead of the covariance-update one")
                .opt("topology", "star | tree (must match the leader's --topology)", Some("star"))
                .opt("connect-timeout-secs", "how long to retry reaching the leader", Some("30")),
        )
        .command(
            CommandSpec::new("online", "distributed truncated-gradient baseline (§4.3 grid)")
                .opt("kind", "synthetic kind", Some("dna"))
                .opt("examples", "synthetic examples", Some("10000"))
                .opt("features", "synthetic features", Some("400"))
                .opt("machines", "example shards M", Some("4"))
                .opt("passes", "online passes", Some("10"))
                .opt("seed", "rng seed", Some("1")),
        )
        .command(
            CommandSpec::new("evaluate", "score a saved model on a libsvm test set")
                .opt("model", "model path", None)
                .opt("input", "libsvm test path", None),
        )
        .command(
            CommandSpec::new("predict", "score a libsvm file offline with a saved model (ndjson; lines are byte-identical to /predict_batch output)")
                .opt("model", "model artifact path", None)
                .opt("input", "libsvm input path", None)
                .opt("family", "assert the artifact's GLM family (errors on mismatch)", None)
                .opt("out", "write ndjson here instead of stdout", None),
        )
        .command(
            CommandSpec::new("serve", "serve a trained model artifact over HTTP (POST /predict, /predict_batch; hot-swaps when the artifact changes)")
                .opt("model", "trained model artifact path (watched for hot-swap)", None)
                .opt("family", "assert the artifact's GLM family (errors on mismatch)", None)
                .opt("config", "TOML file with a [serve] section", None)
                .opt("listen", "bind address host:port (port 0 = ephemeral; overrides [serve] listen)", None)
                .opt("threads", "accept threads (overrides [serve] threads)", None)
                .opt("max-batch", "max examples per /predict_batch request (overrides [serve] max_batch)", None)
                .opt("poll-interval-secs", "artifact watch cadence (overrides [serve] poll_interval_secs)", None)
                .flag("no-watch", "disable the artifact watcher (no hot-swap)"),
        )
}

fn synth_by_kind(kind: &str, n: usize, p: usize, nnz_row: usize, seed: u64) -> Result<Dataset> {
    match kind {
        "epsilon" => Ok(synth::epsilon_like(n, p, seed)),
        "webspam" => Ok(synth::webspam_like(n, p, nnz_row, seed)),
        "dna" => Ok(synth::dna_like(n, p, nnz_row, seed)),
        other => Err(DlrError::Cli(format!("unknown kind '{other}'"))),
    }
}

fn load_or_generate(args: &ParsedArgs) -> Result<Dataset> {
    if let Some(path) = args.get_str("input") {
        libsvm::read_libsvm_file(path)
    } else {
        synth_by_kind(
            args.get_str("kind").unwrap_or("dna"),
            args.get_usize("examples")?.unwrap_or(10_000),
            args.get_usize("features")?.unwrap_or(400),
            args.get_usize("nnz-per-row")?.unwrap_or(12),
            args.get_u64("seed")?.unwrap_or(1),
        )
    }
}

fn train_config(args: &ParsedArgs) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(l) = args.get_f64("lambda")? {
        cfg.lambda = l;
    }
    if let Some(f) = args.get_str("family") {
        cfg.family = FamilyKind::parse_or_err(f)?;
    }
    if let Some(a) = args.get_f64("alpha")? {
        // range-validated by cfg.validate() below (must be in (0, 1])
        cfg.enet_alpha = a;
    }
    if let Some(m) = args.get_usize("machines")? {
        cfg.machines = m;
    }
    if let Some(w) = args.get_usize("workers")? {
        // --workers is the protocol-era alias; it wins over --machines
        cfg.machines = w;
    }
    if let Some(s) = args.get_str("store") {
        cfg.store = Some(s.to_string());
    }
    if let Some(s) = args.get_str("transport") {
        cfg.transport = TransportKind::parse(s)
            .ok_or_else(|| DlrError::Cli(format!("unknown transport '{s}'")))?;
    }
    if let Some(l) = args.get_str("listen") {
        cfg.listen = l.to_string();
    }
    if let Some(t) = args.get_str("topology") {
        cfg.topology = TopologyKind::parse(t)
            .ok_or_else(|| DlrError::Cli(format!("unknown topology '{t}'")))?;
    }
    if let Some(e) = args.get_str("engine") {
        cfg.engine = EngineKind::parse(e)
            .ok_or_else(|| DlrError::Cli(format!("unknown engine '{e}'")))?;
    }
    if let Some(t) = args.get_usize("sweep-threads")? {
        cfg.sweep_threads = t;
    }
    if args.get_flag("naive-sweep") {
        cfg.naive_sweep = true;
    }
    if let Some(i) = args.get_usize("max-iter")? {
        cfg.max_iter = i;
    }
    if let Some(t) = args.get_f64("tol")? {
        cfg.tol = t;
    }
    if let Some(s) = args.get_str("exchange") {
        cfg.exchange = ExchangeStrategy::parse(s)
            .ok_or_else(|| DlrError::Cli(format!("unknown exchange strategy '{s}'")))?;
    }
    if args.get_flag("wire-f16") {
        cfg.wire_f16_margins = true;
    }
    if args.get_flag("supervise") {
        cfg.supervise = true;
    }
    if let Some(h) = args.get_f64("heartbeat-timeout-secs")? {
        cfg.heartbeat_timeout_secs = h;
    }
    if let Some(r) = args.get_f64("recv-timeout-secs")? {
        cfg.recv_timeout_secs = r;
    }
    if let Some(k) = args.get_usize("recovery-checkpoint-every")? {
        cfg.recovery_checkpoint_every = k;
    }
    if let Some(w) = args.get_f64("max-secs")? {
        cfg.budget.wall_secs = Some(w);
    }
    if let Some(b) = args.get_u64("max-comm-bytes")? {
        cfg.budget.comm_bytes = Some(b);
    }
    if let Some(i) = args.get_usize("budget-iters")? {
        cfg.budget.iterations = Some(i);
    }
    cfg.verbose = args.get_flag("verbose");
    cfg.validate()?;
    Ok(cfg)
}

fn summary_table(datasets: &[&Dataset]) -> Table {
    let mut t = Table::new(
        "Datasets (paper Table 2 analog)",
        &["dataset", "#examples", "#features", "nnz", "avg nonzeros", "positives"],
    );
    for ds in datasets {
        let s = ds.summary();
        t.add_row(vec![
            s.name,
            s.n_examples.to_string(),
            s.n_features.to_string(),
            s.nnz.to_string(),
            format!("{:.1}", s.avg_nonzeros),
            s.positives.to_string(),
        ]);
    }
    t
}

fn cmd_gen_data(args: &ParsedArgs) -> Result<()> {
    let ds = load_or_generate(args)?;
    summary_table(&[&ds]).print();
    if !args.get_flag("summary") {
        let out = args.get_str("out").unwrap_or("data.svm");
        libsvm::write_libsvm(&ds, std::fs::File::create(out)?)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_transform(args: &ParsedArgs) -> Result<()> {
    let input = args
        .get_str("input")
        .ok_or_else(|| DlrError::Cli("--input is required".into()))?;
    let ds = libsvm::read_libsvm_file(input)?;
    let csc = ds.x.to_csc();
    let out = args.get_str("out").unwrap_or("data.byfeature");
    libsvm::write_by_feature(&csc, std::fs::File::create(out)?)?;
    println!(
        "transformed {} ({} examples, {} features, {} nnz) -> {out}",
        input,
        ds.n_examples(),
        ds.n_features(),
        ds.x.nnz()
    );
    Ok(())
}

fn print_fit(name: &str, lambda: f64, fit: &FitResult, test: &Dataset) {
    let margins = fit.model.predict_margins(&test.x);
    let family = fit.model.family;
    let mut t = Table::new(
        format!("{name} fit @ lambda = {lambda:.5} ({} family)", family.name()),
        &["solver", "iters", "converged", "objective", "nnz", "test AUPRC", "test AUC", "test deviance", "sim comm (s)", "bytes"],
    );
    t.add_row(vec![
        name.to_string(),
        fit.iterations.to_string(),
        fit.converged.to_string(),
        format!("{:.5}", fit.objective),
        fit.nnz().to_string(),
        format!("{:.4}", metrics::auprc(&margins, &test.y)),
        format!("{:.4}", metrics::roc_auc(&margins, &test.y)),
        format!("{:.4}", metrics::deviance(&margins, &test.y, family)),
        format!("{:.4}", fit.sim_comm_secs),
        fit.comm_bytes.to_string(),
    ]);
    t.print();
}

fn announce_socket(cfg: &TrainConfig) {
    if cfg.transport == TransportKind::Socket {
        println!(
            "listening on {} for {} worker nodes (launch them with \
             `dglmnet worker --connect {} --machine <k> ...`)",
            cfg.listen, cfg.machines, cfg.listen
        );
    }
}

/// The d-GLMNET train path drives the stepwise `FitDriver` directly — this
/// is the checkpoint/resume/budget workflow the stepwise API exists for.
fn drive_stepwise(args: &ParsedArgs, solver: &mut DGlmnetSolver) -> Result<FitResult> {
    let lambda = solver.cfg.lambda;
    let mut driver = match args.get_str("resume") {
        Some(path) => {
            let ck = Checkpoint::load(path)?;
            println!("resuming from {path} (iteration {})", ck.iter);
            solver.driver_from_checkpoint(&ck)?
        }
        None => solver.driver(lambda),
    };
    let ckpt_out = args.get_str("checkpoint-out");
    let every = args.get_usize("checkpoint-every")?.unwrap_or(10).max(1);
    loop {
        match driver.step()? {
            StepOutcome::Progress(rec) => {
                if let Some(path) = ckpt_out {
                    if rec.iter % every == 0 {
                        driver.checkpoint()?.save(path)?;
                    }
                }
            }
            StepOutcome::Finished { reason, .. } => {
                if let Some(path) = ckpt_out {
                    driver.checkpoint()?.save(path)?;
                    println!("checkpoint written to {path} ({reason:?})");
                }
                break;
            }
        }
    }
    Ok(driver.finish())
}

fn train_dglmnet(args: &ParsedArgs, train: &Dataset) -> Result<(FitResult, (u64, u64))> {
    let cfg = train_config(args)?;
    announce_socket(&cfg);
    let mut solver = DGlmnetSolver::from_dataset(train, &cfg)?;
    let fit = drive_stepwise(args, &mut solver)?;
    Ok((fit, solver.leader_wire_bytes()))
}

/// Out-of-core train path: every worker self-loads its shard file from the
/// store named by `cfg.store` and the leader touches only the manifest,
/// the shard headers and `y.bin` — it never constructs a matrix of X.
/// Returns the fit plus the store's example count (artifact metadata).
fn train_dglmnet_from_store(args: &ParsedArgs) -> Result<(FitResult, usize, (u64, u64))> {
    let cfg = train_config(args)?;
    let dir = cfg.store.clone().ok_or_else(|| {
        DlrError::Cli("the store train path needs --store <dir>".into())
    })?;
    let store = ShardStore::open(&dir)?;
    println!(
        "store {dir}: {} examples x {} features over {} machines ({} partition)",
        store.n(),
        store.p(),
        store.machines(),
        store.manifest().partition
    );
    announce_socket(&cfg);
    let n = store.n();
    let mut solver = DGlmnetSolver::from_store(&store, &cfg)?;
    let fit = drive_stepwise(args, &mut solver)?;
    let wire = solver.leader_wire_bytes();
    Ok((fit, n, wire))
}

fn train_baseline(kind: &str, args: &ParsedArgs, train: &Dataset) -> Result<FitResult> {
    let lambda = args.get_f64("lambda")?.unwrap_or(1.0);
    let seed = args.get_u64("seed")?.unwrap_or(1);
    let passes = args.get_usize("passes")?.unwrap_or(10);
    let lr = args.get_f64("learning-rate")?.unwrap_or(0.3);
    let decay = args.get_f64("decay")?.unwrap_or(0.7);
    let machines = args.get_usize("machines")?.unwrap_or(4);
    let parallelism = args.get_usize("parallelism")?.unwrap_or(8);
    let rounds = args.get_usize("rounds")?.unwrap_or(200);
    // the dglmnet path validates through TrainConfig; validate the baseline
    // knobs here so bad flags fail as config errors, not panics
    if lambda < 0.0 {
        return Err(DlrError::Cli("--lambda must be >= 0".into()));
    }
    if machines == 0 || passes == 0 || parallelism == 0 || rounds == 0 {
        return Err(DlrError::Cli(
            "--machines, --passes, --parallelism and --rounds must be >= 1".into(),
        ));
    }
    if lr <= 0.0 || decay <= 0.0 || decay > 1.0 {
        return Err(DlrError::Cli(
            "--learning-rate must be > 0 and --decay in (0, 1]".into(),
        ));
    }
    let mut est: Box<dyn Estimator> = match kind {
        "shotgun" => Box::new(ShotgunEstimator::new(lambda, parallelism, rounds, seed)),
        "truncgrad" => {
            Box::new(TruncatedGradientEstimator::new(lr, decay, lambda, passes, seed))
        }
        "online" => Box::new(DistributedOnlineEstimator::new(
            machines, lr, decay, lambda, passes, seed,
        )),
        other => return Err(DlrError::Cli(format!("unknown solver '{other}'"))),
    };
    fit_cold(est.as_mut(), train, &mut NoopObserver)
}

fn cmd_train(args: &ParsedArgs) -> Result<()> {
    let kind = args.get_str("solver").unwrap_or("dglmnet").to_string();
    // out-of-core: train straight from a sharded store (no test split —
    // the store holds exactly the training rows; score separately with
    // `evaluate`)
    if args.get_str("store").is_some() {
        if kind != "dglmnet" {
            return Err(DlrError::Cli(
                "--store drives the distributed d-GLMNET solver; the in-memory \
                 baselines need --input/--kind data"
                    .into(),
            ));
        }
        let (fit, n_examples, wire) = train_dglmnet_from_store(args)?;
        println!(
            "store fit @ lambda = {:.5}: f = {:.6}, nnz = {}, {} iters, converged = {}, \
             {} comm bytes",
            fit.lambda,
            fit.objective,
            fit.nnz(),
            fit.iterations,
            fit.converged,
            fit.comm_bytes
        );
        finish_train_output(args, &fit, n_examples, &kind, Some(wire))?;
        return Ok(());
    }
    let ds = load_or_generate(args)?;
    let split = ds.split(0.8, args.get_u64("seed")?.unwrap_or(1))?;
    let (fit, wire) = match kind.as_str() {
        "dglmnet" => {
            let (fit, wire) = train_dglmnet(args, &split.train)?;
            (fit, Some(wire))
        }
        other => (train_baseline(other, args, &split.train)?, None),
    };
    print_fit(&kind, fit.lambda, &fit, &split.test);
    finish_train_output(args, &fit, split.train.n_examples(), &kind, wire)?;
    Ok(())
}

/// The machine-readable tail every train run prints: the exact objective
/// bit pattern (the CI socket job diffs this across transports) and the
/// leader's peak RSS (the out-of-core job gates this against the full-load
/// watermark).
fn finish_train_output(
    args: &ParsedArgs,
    fit: &FitResult,
    n_examples: usize,
    solver: &str,
    wire: Option<(u64, u64)>,
) -> Result<()> {
    println!("objective_bits={:016x}", fit.objective.to_bits());
    if solver == "dglmnet" {
        // the resolved sweep-kernel choice (what the workers' native
        // engines actually ran), next to the other machine-readable lines
        let cfg = train_config(args)?;
        let kernel = dglmnet::engine::SweepKernel::from_config(&cfg);
        println!(
            "sweep_kernel={} sweep_threads={}",
            kernel.kernel_name(),
            kernel.threads
        );
    }
    println!(
        "leader_peak_rss_bytes={}",
        dglmnet::util::peak_rss_bytes().unwrap_or(0)
    );
    if let Some((sent, recv)) = wire {
        // measured at the leader's own worker links (frame bytes, both
        // directions; the in-process pool counts what its messages would
        // frame to) — under `--topology tree` the data-plane share stays
        // O(1) in the worker count
        println!("leader_wire_bytes_sent={sent} leader_wire_bytes_recv={recv}");
    }
    if let Some(path) = args.get_str("model-out") {
        // embed the artifact metadata (training-set size, solver) the
        // serve/predict loaders surface and checksum over
        let model = fit.model.clone().with_meta(n_examples, solver);
        model.save(path)?;
        println!("model saved to {path} (version {:016x})", model.checksum());
    }
    Ok(())
}

/// Shard a dataset into an on-disk store: one by-feature shard file per
/// machine plus the manifest — the preprocessing step of out-of-core
/// training (`train --store` / `worker --store`).
fn cmd_shard(args: &ParsedArgs) -> Result<()> {
    let ds = load_or_generate(args)?;
    let frac = args.get_f64("train-frac")?.unwrap_or(1.0);
    if !(0.0..=1.0).contains(&frac) {
        return Err(DlrError::Cli(format!(
            "--train-frac must be within [0, 1], got {frac}"
        )));
    }
    let seed = args.get_u64("seed")?.unwrap_or(1);
    // the SAME deterministic split `train` applies, so a store built with
    // --train-frac 0.8 holds exactly the rows `dglmnet train` would fit on
    let ds = if frac < 1.0 { ds.split(frac, seed)?.train } else { ds };
    let machines = match args.get_usize("workers")? {
        Some(w) => w,
        None => args.get_usize("machines")?.unwrap_or(4),
    };
    let strategy_name = args.get_str("partition").unwrap_or("round-robin");
    let strategy = PartitionStrategy::parse(strategy_name)
        .ok_or_else(|| DlrError::Cli(format!("unknown partition '{strategy_name}'")))?;
    if machines == 0 {
        return Err(DlrError::Cli("--machines must be >= 1".into()));
    }
    let cfg = TrainConfig::builder().machines(machines).partition(strategy).build();
    cfg.validate_machines_for(ds.n_features())?;
    // identical partition to what a leader/worker derives from the same
    // flags (validated again by the Join handshake at fit time)
    let partition = DGlmnetSolver::partition_for(&ds, &cfg);
    let out = args.get_str("out").unwrap_or("store");
    let store = if args.get_flag("in-memory") {
        ShardStore::create(out, &ds, &partition, strategy.name())?
    } else {
        let (store, stats) = shuffle_to_store(&ds, &partition, strategy.name(), out.as_ref())?;
        println!(
            "external shuffle: {} triplets, {} spill bytes, map {:.2}s, reduce {:.2}s",
            stats.triplets, stats.spill_bytes, stats.map_secs, stats.reduce_secs
        );
        store
    };
    let mut t = Table::new(
        format!("sharded store at {out}"),
        &["machine", "features", "nnz", "cols checksum"],
    );
    for s in &store.manifest().shards {
        t.add_row(vec![
            s.machine.to_string(),
            s.local_features.to_string(),
            s.nnz.to_string(),
            format!("{:016x}", s.cols_checksum),
        ]);
    }
    t.print();
    println!(
        "wrote {out}: {} examples x {} features over {} machines — train with \
         `dglmnet train --store {out} --workers {}` (workers: `dglmnet worker \
         --store {out} --machine <k> ...`)",
        store.n(),
        store.p(),
        store.machines(),
        store.machines()
    );
    Ok(())
}

/// One remote worker node: load its shard from a store (`--store`), or
/// rebuild it from data flags identical to the leader's; connect, and
/// serve the node protocol until the leader shuts the fit down.
fn cmd_worker(args: &ParsedArgs) -> Result<()> {
    let connect = args
        .get_str("connect")
        .ok_or_else(|| DlrError::Cli("--connect is required".into()))?
        .to_string();
    let machine = args
        .get_usize("machine")?
        .ok_or_else(|| DlrError::Cli("--machine is required".into()))?;
    let cfg = train_config(args)?;
    let artifacts = dglmnet::runtime::default_artifacts_dir();
    let mut node = if let Some(dir) = args.get_str("store") {
        // out-of-core: read *only this machine's* shard file (+ y.bin)
        let store = ShardStore::open(dir)?;
        if machine >= store.machines() {
            return Err(DlrError::Cli(format!(
                "--machine {machine} is out of range for the {}-machine store at {dir}",
                store.machines()
            )));
        }
        WorkerNode::from_store(&cfg, &store, machine, &artifacts)?
    } else {
        let ds = load_or_generate(args)?;
        let split = ds.split(0.8, args.get_u64("seed")?.unwrap_or(1))?;
        let train = &split.train;
        cfg.validate_machines_for(train.n_features())?;
        if machine >= cfg.machines {
            return Err(DlrError::Cli(format!(
                "--machine {machine} is out of range for a {}-worker cluster",
                cfg.machines
            )));
        }
        let shard = DGlmnetSolver::shard_for(train, &cfg, machine);
        WorkerNode::from_shard(
            &cfg,
            shard,
            std::sync::Arc::new(train.y.clone()),
            train.n_features(),
            &artifacts,
        )?
    };
    let timeout = args.get_u64("connect-timeout-secs")?.unwrap_or(30);
    println!(
        "worker {machine}: engine {}, joining {connect}",
        node.engine_name()
    );
    let mut transport =
        SocketTransport::connect_retry(connect.as_str(), Duration::from_secs(timeout))?;
    // under the tree topology the worker listens for its bracket peers on
    // an ephemeral port of the same interface that reaches the leader; the
    // Join announces it and the Welcome's topology wires up the links
    let mut peers = if cfg.topology == TopologyKind::Tree {
        Some(PeerTable::bind(transport.local_ip()?)?)
    } else {
        None
    };
    node.serve(&mut transport, peers.as_mut())?;
    println!("worker {machine}: leader finished, shutting down");
    Ok(())
}

fn cmd_path(args: &ParsedArgs) -> Result<()> {
    let ds = load_or_generate(args)?;
    let split = ds.split(0.8, args.get_u64("seed")?.unwrap_or(1))?;
    let cfg = train_config(args)?;
    let path_cfg = PathConfig {
        steps: args.get_usize("steps")?.unwrap_or(20),
        max_iter_per_lambda: args.get_usize("max-iter")?.unwrap_or(50),
        ..Default::default()
    };
    let path = RegPath::run(&split.train, &split.test, &cfg, &path_cfg)?;
    let mut t = Table::new(
        "regularization path (Algorithm 5)",
        &["lambda", "nnz", "test AUPRC", "test AUC", "iters", "wall (s)", "LS frac"],
    );
    for p in &path.points {
        t.add_row(vec![
            format!("{:.5}", p.lambda),
            p.nnz.to_string(),
            format!("{:.4}", p.auprc),
            format!("{:.4}", p.auc),
            p.iterations.to_string(),
            format!("{:.3}", p.wall_secs),
            format!("{:.0}%", p.line_search_frac * 100.0),
        ]);
    }
    t.print();
    println!(
        "total: {} iters, {:.2}s wall, {:.4}s simulated comm, {} bytes moved",
        path.total_iterations,
        path.total_wall_secs,
        path.total_sim_comm_secs,
        path.total_comm_bytes
    );
    if let Some(csv) = args.get_str("csv-out") {
        let mut s = dglmnet::report::Series::new("d-glmnet");
        for p in &path.points {
            s.push(p.nnz as f64, p.auprc);
        }
        dglmnet::report::write_series_csv(csv, &[s])?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_online(args: &ParsedArgs) -> Result<()> {
    let ds = synth_by_kind(
        args.get_str("kind").unwrap_or("dna"),
        args.get_usize("examples")?.unwrap_or(10_000),
        args.get_usize("features")?.unwrap_or(400),
        12,
        args.get_u64("seed")?.unwrap_or(1),
    )?;
    let split = ds.split(0.8, 1)?;
    let lam_max = dglmnet::solver::lambda_max(&split.train);
    let lambdas: Vec<f64> = (1..=8).map(|i| lam_max * 0.5f64.powi(i)).collect();
    let pts = online_grid_search(
        &split.train,
        &split.test,
        args.get_usize("machines")?.unwrap_or(4),
        &[0.1, 0.3, 0.5],
        &[0.5, 0.9],
        &lambdas,
        args.get_usize("passes")?.unwrap_or(10),
        args.get_u64("seed")?.unwrap_or(1),
    );
    let mut t = Table::new(
        "online baseline frontier (best AUPRC per sparsity)",
        &["nnz", "AUPRC"],
    );
    for (nnz, auprc) in dglmnet::baselines::grid::grid_frontier(&pts) {
        t.add_row(vec![nnz.to_string(), format!("{auprc:.4}")]);
    }
    t.print();
    println!("{} grid points evaluated", pts.len());
    Ok(())
}

fn cmd_evaluate(args: &ParsedArgs) -> Result<()> {
    let model = SparseModel::load(
        args.get_str("model")
            .ok_or_else(|| DlrError::Cli("--model is required".into()))?,
    )?;
    let ds = libsvm::read_libsvm_file(
        args.get_str("input")
            .ok_or_else(|| DlrError::Cli("--input is required".into()))?,
    )?;
    let margins = model.predict_margins(&ds.x);
    let mut t = Table::new("evaluation", &["nnz", "AUPRC", "AUC", "logloss", "accuracy"]);
    t.add_row(vec![
        model.nnz().to_string(),
        format!("{:.4}", metrics::auprc(&margins, &ds.y)),
        format!("{:.4}", metrics::roc_auc(&margins, &ds.y)),
        format!("{:.4}", metrics::mean_logloss(&margins, &ds.y)),
        format!("{:.4}", metrics::accuracy(&margins, &ds.y)),
    ]);
    t.print();
    Ok(())
}

/// `--family` on predict/serve is an assertion, not a conversion: the
/// artifact must record (or default to) exactly that family, otherwise
/// scoring would silently reinterpret its margins through the wrong link.
fn assert_artifact_family(args: &ParsedArgs, model: &SparseModel) -> Result<()> {
    if let Some(f) = args.get_str("family") {
        let want = FamilyKind::parse_or_err(f)?;
        if want != model.family {
            return Err(DlrError::Cli(format!(
                "--family {} but the model artifact was fitted as {} — drop the \
                 flag (or pass --family {}) to score it as fitted, or retrain \
                 with the family you want",
                want.name(),
                model.family.name(),
                model.family.name()
            )));
        }
    }
    Ok(())
}

/// Offline scorer: one [`dglmnet::serve::prediction_line`] per input row,
/// byte-identical to what `/predict_batch` streams for the same examples —
/// the serve_e2e CI job diffs the two outputs directly. The `proba` field
/// is the model family's mean prediction (sigmoid probability for
/// logistic, identity/exp for gaussian/poisson).
fn cmd_predict(args: &ParsedArgs) -> Result<()> {
    let model = SparseModel::load(
        args.get_str("model")
            .ok_or_else(|| DlrError::Cli("--model is required".into()))?,
    )?;
    assert_artifact_family(args, &model)?;
    let ds = libsvm::read_libsvm_file(
        args.get_str("input")
            .ok_or_else(|| DlrError::Cli("--input is required".into()))?,
    )?;
    let margins = model.predict_margins(&ds.x);
    let fam = model.family.family();
    let mut out: Box<dyn Write> = match args.get_str("out") {
        Some(p) => Box::new(std::io::BufWriter::new(std::fs::File::create(p)?)),
        None => Box::new(std::io::BufWriter::new(std::io::stdout())),
    };
    for (i, &m) in margins.iter().enumerate() {
        let mean = fam.mean(m as f64) as f32;
        writeln!(out, "{}", dglmnet::serve::prediction_line(i, m, mean))?;
    }
    out.flush()?;
    eprintln!(
        "scored {} examples (model: p = {}, nnz = {}, lambda = {}, family = {}, \
         version {:016x})",
        margins.len(),
        model.n_features,
        model.nnz(),
        model.lambda,
        model.family.name(),
        model.checksum()
    );
    Ok(())
}

fn cmd_serve(args: &ParsedArgs) -> Result<()> {
    let model_path = args
        .get_str("model")
        .ok_or_else(|| DlrError::Cli("--model is required".into()))?;
    if args.get_str("family").is_some() {
        // validate the family assertion before binding anything
        assert_artifact_family(args, &SparseModel::load(model_path)?)?;
    }
    let mut cfg = match args.get_str("config") {
        Some(path) => dglmnet::config::ServeConfig::from_file(path)?,
        None => dglmnet::config::ServeConfig::default(),
    };
    if let Some(l) = args.get_str("listen") {
        cfg.listen = l.to_string();
    }
    if let Some(t) = args.get_usize("threads")? {
        cfg.threads = t;
    }
    if let Some(b) = args.get_usize("max-batch")? {
        cfg.max_batch = b;
    }
    if let Some(p) = args.get_f64("poll-interval-secs")? {
        cfg.poll_interval_secs = p;
    }
    if args.get_flag("no-watch") {
        cfg.watch = false;
    }
    cfg.validate()?;
    let handle = dglmnet::serve::Server::start(model_path, &cfg)?;
    let m = handle.slot.get();
    // the machine-readable ready line clients wait for (stdout is
    // line-buffered, so this flushes before the blocking wait)
    println!(
        "serve_ready addr={} model_version={} p={} nnz={} lambda={} watch={} family={}",
        handle.addr,
        m.version,
        m.model.n_features,
        m.model.nnz(),
        m.model.lambda,
        cfg.watch,
        m.model.family.name()
    );
    handle.wait();
    Ok(())
}

fn run() -> Result<()> {
    let app = app();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = app.parse(&args)?;
    match parsed.command.as_str() {
        "help" => {
            print!("{}", app.usage());
            Ok(())
        }
        "gen-data" => cmd_gen_data(&parsed),
        "transform" => cmd_transform(&parsed),
        "shard" => cmd_shard(&parsed),
        "train" => cmd_train(&parsed),
        "worker" => cmd_worker(&parsed),
        "path" => cmd_path(&parsed),
        "online" => cmd_online(&parsed),
        "evaluate" => cmd_evaluate(&parsed),
        "predict" => cmd_predict(&parsed),
        "serve" => cmd_serve(&parsed),
        other => Err(DlrError::Cli(format!("unhandled command '{other}'"))),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}
