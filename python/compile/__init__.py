"""Build-time python package: L1 Pallas kernels + L2 JAX model + AOT lowering.

Never imported at runtime — the rust coordinator consumes only the HLO-text
artifacts this package emits via `make artifacts`.
"""
