"""L1 Pallas kernel: dense block mat-vec, m += X @ v.

Used to (re)build margins from a coefficient block — warmstart margins at a
new lambda on the regularization path, and test-set prediction in the XLA
engine. (N, B) x (B,) rides the MXU with the block resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(x_ref, v_ref, acc_ref, out_ref):
    out_ref[...] = acc_ref[...] + jnp.dot(
        x_ref[...], v_ref[...], precision=jax.lax.Precision.HIGHEST
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matvec_block(X, v, acc, *, interpret=True):
    """-> acc + X @ v, shape (N,)."""
    n = X.shape[0]
    return pl.pallas_call(
        _matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(X, v, acc)
