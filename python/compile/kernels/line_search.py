"""L1 Pallas kernel: the O(n) part of the d-GLMNET line search (paper Alg 3).

The paper's key systems claim is that the line search needs only O(n + p)
state: per-example margins m and margin deltas dm. This kernel evaluates the
masked logistic loss

    L(alpha_k) = sum_i mask_i * log(1 + exp(-y_i (m_i + alpha_k dm_i)))

for a whole grid of K candidate alphas in one pass: the (K, N) broadcast is
materialized tile-by-tile in VMEM and row-reduced. Evaluating the grid at
once amortizes the HBM read of (m, dm, y) across all K candidates — the
alpha_init scan of Alg 3 step 2 and the Armijo backtracking sequence
{alpha_init * b^j} both become a single kernel call.

The L1 penalty part of f(beta + alpha*dbeta) is O(p) and handled by the rust
leader.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _line_search_kernel(m_ref, dm_ref, y_ref, mask_ref, alphas_ref, out_ref):
    m = m_ref[...]
    dm = dm_ref[...]
    ym = y_ref[...] * mask_ref[...]
    alphas = alphas_ref[...]
    # (K, N): t_{k,i} = -y_i (m_i + a_k dm_i); padded rows give t = 0 and a
    # mask-scaled loss of 0 because we multiply log1p(exp(.)) terms by mask.
    t = -(ym[None, :] * (m[None, :] + alphas[:, None] * dm[None, :]))
    loss = jnp.maximum(t, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(t)))
    out_ref[...] = jnp.sum(loss * mask_ref[...][None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def line_search_grid(margins, dmargins, y, mask, alphas, *, interpret=True):
    """-> (K,) masked logistic-loss sums at beta + alpha_k * dbeta."""
    k = alphas.shape[0]
    return pl.pallas_call(
        _line_search_kernel,
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(margins, dmargins, y, mask, alphas)
