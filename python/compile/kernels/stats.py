"""L1 Pallas kernel: per-example logistic statistics (paper eq. (4)).

Given margins m_i = beta.x_i, labels y_i and a validity mask, compute in one
pass the GLMNET working weights/responses and the masked log-loss:

    p = sigmoid(m);  w = mask * p(1-p);  z = mask * ((y+1)/2 - p)/max(p(1-p), eps)
    loss_sum = sum_i mask_i * log(1 + exp(-y_i m_i))

Elementwise over (N,) — on TPU this is VPU work streamed through VMEM; the
mask folds zero-padded tiles out of every downstream reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

W_EPS = 1e-10


def _stats_kernel(m_ref, y_ref, mask_ref, w_ref, z_ref, loss_ref):
    m = m_ref[...]
    y = y_ref[...]
    mask = mask_ref[...]
    p = 1.0 / (1.0 + jnp.exp(-m))
    w = p * (1.0 - p)
    z = ((y + 1.0) / 2.0 - p) / jnp.maximum(w, W_EPS)
    t = -y * m
    loss = jnp.maximum(t, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(t)))
    w_ref[...] = w * mask
    z_ref[...] = z * mask
    loss_ref[...] = jnp.sum(loss * mask)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def logistic_stats(margins, y, mask, *, interpret=True):
    """-> (w, z, loss_sum[1]) with shapes ((N,), (N,), (1,))."""
    n = margins.shape[0]
    return pl.pallas_call(
        _stats_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=interpret,
    )(margins, y, mask)
