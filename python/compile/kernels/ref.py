"""Pure-numpy correctness oracles for the Pallas kernels.

These are deliberately written as straightforward, loop-heavy float64 numpy
code — an *independent* code path from the Pallas kernels — so that a bug in
the kernel cannot be mirrored in the oracle.

Math (paper eq. (4)-(6), with the nu-regularized Hessian of Section 2):

    p_i = sigmoid(beta . x_i)
    w_i = p_i (1 - p_i)
    z_i = ((y_i + 1)/2 - p_i) / w_i

One cyclic coordinate-descent sweep over a feature block solves

    argmin_{dbeta}  1/2 sum_i w_i (z_i - dbeta . x_i)^2
                    + nu/2 ||dbeta||^2 + lam ||beta + dbeta||_1

per-coordinate closed form (eq. (6) extended with the nu term):

    A_j   = sum_i w_i x_ij^2 + nu
    c_j   = sum_i w_i x_ij r_i + u_j (A_j - nu) + beta_j A_j
    s_j   = soft_threshold(c_j, lam) / A_j          # new beta_j + dbeta_j
    r_i  -= (s_j - beta_j - u_j) x_ij               # maintain r = z - dbeta.x

where r_i = z_i - dbeta . x_i is the working residual and u_j the current
dbeta_j. `w == 0` rows (padding) contribute nothing anywhere.
"""

from __future__ import annotations

import numpy as np

W_EPS = 1e-10  # guard for z = (...)/w on saturated examples


def soft_threshold(x: float, a: float) -> float:
    return np.sign(x) * max(abs(x) - a, 0.0)


def ref_logistic_stats(margins, y, mask):
    """-> (w, z, loss_sum). float64 numpy oracle.

    margins: (N,) beta.x_i ; y: (N,) in {-1,+1} (anything on masked rows);
    mask: (N,) {0,1}. Returns masked w, z and the masked log-loss sum.
    """
    m = np.asarray(margins, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    p = 1.0 / (1.0 + np.exp(-m))
    w = p * (1.0 - p)
    z = ((y + 1.0) / 2.0 - p) / np.maximum(w, W_EPS)
    # stable log(1 + exp(-y m))
    t = -y * m
    loss = np.maximum(t, 0.0) + np.log1p(np.exp(-np.abs(t)))
    return w * mask, z * mask, float(np.sum(loss * mask))


def ref_cd_block_sweep(X, w, r, beta, delta, lam, nu):
    """One cyclic CD sweep over the columns of dense block X.

    X: (N, B); w, r: (N,); beta, delta: (B,) — beta is the *current* global
    coefficient for these features, delta the accumulated update so far this
    outer iteration. Returns (delta_new, r_new), both float64.
    """
    X = np.asarray(X, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    r = np.array(r, dtype=np.float64)
    beta = np.asarray(beta, dtype=np.float64)
    delta = np.array(delta, dtype=np.float64)
    N, B = X.shape
    for j in range(B):
        x = X[:, j]
        A = float(np.dot(w, x * x)) + nu
        u = delta[j]
        c = float(np.dot(w * r, x)) + u * (A - nu) + beta[j] * A
        s = soft_threshold(c, lam) / A
        step = s - beta[j] - u
        delta[j] = s - beta[j]
        r = r - step * x
    return delta, r


def ref_line_search_grid(margins, dmargins, y, mask, alphas):
    """Masked logistic loss at beta + alpha * dbeta for each alpha.

    -> (K,) float64: sum_i mask_i log(1 + exp(-y_i (m_i + alpha_k dm_i)))
    (the L1 term is handled by the caller; it needs only O(p) data).
    """
    m = np.asarray(margins, dtype=np.float64)
    dm = np.asarray(dmargins, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    out = []
    for a in np.asarray(alphas, dtype=np.float64):
        t = -y * (m + a * dm)
        loss = np.maximum(t, 0.0) + np.log1p(np.exp(-np.abs(t)))
        out.append(float(np.sum(loss * mask)))
    return np.array(out)


def ref_matvec(X, v):
    """(N, B) @ (B,) in float64."""
    return np.asarray(X, dtype=np.float64) @ np.asarray(v, dtype=np.float64)


def ref_full_quadratic_objective(X, w, z, beta, delta, lam, nu):
    """Value of the (block) quadratic subproblem objective — used by tests to
    assert that a sweep never increases it.

    1/2 sum w (z - delta.x)^2 + nu/2 ||delta||^2 + lam ||beta + delta||_1
    """
    X = np.asarray(X, dtype=np.float64)
    resid = np.asarray(z, dtype=np.float64) - X @ np.asarray(delta, np.float64)
    quad = 0.5 * float(np.dot(np.asarray(w, np.float64), resid * resid))
    quad += 0.5 * nu * float(np.dot(delta, delta))
    return quad + lam * float(np.sum(np.abs(np.asarray(beta) + np.asarray(delta))))
