"""L1 Pallas kernel: covariance-update cyclic CD sweep — the OPTIMIZED hot
path (EXPERIMENTS.md §Perf iteration 1).

The naive sweep (`cd_sweep.py`) does two (N,)-length reductions per column
inside the sequential loop: O(N·B) serial work the TPU can't batch. The
covariance formulation hoists everything MXU-shaped out of the loop:

    G = Xᵀ diag(w) X            (B × B Gram, one matmul)
    c = Xᵀ (w ⊙ r)              (one matvec)
    loop j = 0..B:              (all O(B) now)
        A   = G[j,j] + nu
        num = c[j] + u_j (A - nu) + beta_j A
        s   = soft_threshold(num, lam) / A
        δ   = s - beta_j - u_j
        c  -= δ G[j, :]          # the covariance update
        delta[j] = s - beta_j
    r -= X @ (delta - delta_in)  (one matvec at the end)

Identical math to the naive kernel (c_j tracks Σ w r x_ij exactly), but the
sequential loop touches only (B,)-vectors: the N-dimension work is three
MXU matmuls. Serial flops drop from O(N·B) to O(B²).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _soft_threshold(x, a):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - a, 0.0)


def _cd_sweep_cov_kernel(x_ref, w_ref, r_ref, beta_ref, delta_ref, lam_ref, nu_ref,
                         delta_out_ref, r_out_ref):
    X = x_ref[...]                        # (N, B)
    w = w_ref[...]
    r = r_ref[...]
    beta = beta_ref[...]
    delta_in = delta_ref[...]
    lam = lam_ref[0]
    nu = nu_ref[0]
    b = X.shape[1]

    wx = X * w[:, None]                   # (N, B) — reused by both matmuls
    # Gram and initial covariance vector: the only O(N) work, all MXU.
    gram = jnp.dot(wx.T, X, precision=jax.lax.Precision.HIGHEST)       # (B, B)
    c0 = jnp.dot(wx.T, r, precision=jax.lax.Precision.HIGHEST)         # (B,)
    diag = jnp.diagonal(gram) + nu                                     # A_j

    def body(j, carry):
        c, delta = carry
        a = diag[j]
        u = jax.lax.dynamic_slice_in_dim(delta, j, 1)[0]
        bj = jax.lax.dynamic_slice_in_dim(beta, j, 1)[0]
        num = jax.lax.dynamic_slice_in_dim(c, j, 1)[0] + u * (a - nu) + bj * a
        s = _soft_threshold(num, lam) / a
        step = s - bj - u
        grow = jax.lax.dynamic_slice_in_dim(gram, j, 1, axis=0)[0]     # G[j, :]
        c = c - step * grow
        delta = jax.lax.dynamic_update_slice_in_dim(delta, (s - bj)[None], j, 0)
        return c, delta

    _, delta = jax.lax.fori_loop(0, b, body, (c0, delta_in))
    # one matvec realizes every residual update at once
    r_out_ref[...] = r - jnp.dot(
        X, delta - delta_in, precision=jax.lax.Precision.HIGHEST
    )
    delta_out_ref[...] = delta


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_block_sweep_cov(X, w, r, beta, delta, lam, nu, *, interpret=True):
    """Covariance-update CD sweep; same signature/contract as
    `cd_block_sweep` (drop-in replacement on the rust side)."""
    n, b = X.shape
    return pl.pallas_call(
        _cd_sweep_cov_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=interpret,
    )(X, w, r, beta, delta, lam, nu)
