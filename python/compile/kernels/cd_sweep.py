"""L1 Pallas kernel: one cyclic coordinate-descent sweep over a dense
feature block — the d-GLMNET per-machine hot loop (paper Alg 2 / eq. (6)).

The worker's feature shard is tiled into (N, B) dense column blocks that live
in VMEM for the whole sweep. The sweep has a true sequential dependency: each
coordinate update changes the working residual r = z - dbeta.x that the next
coordinate reads. We express it as a `fori_loop` over the B columns; per
column the work is two (N,)-length fused reductions (dot products — the
MXU-eligible part) plus an axpy, all on VMEM-resident data.

Per-column closed form (eq. (6) + nu ridge term; see kernels/ref.py for the
derivation):

    A = sum w x^2 + nu
    c = dot(w*r, x) + u*(A - nu) + beta_j*A
    s = soft_threshold(c, lam) / A
    r -= (s - beta_j - u) * x ;  delta_j = s - beta_j

Zero columns (block padding) have A = nu, c = 0 => delta stays 0.
Zero-weight rows (example padding) are inert in every reduction.

HARDWARE ADAPTATION: the paper streams sparse columns from disk on a CPU
cluster. On TPU the analogue is the BlockSpec HBM->VMEM schedule over column
blocks; the per-column reductions ride the VPU/MXU instead of scalar CPU
loops. The column-denominator precompute `wx2 = w @ (X*X)` is a single
(1,N)x(N,B) matmul on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _soft_threshold(x, a):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - a, 0.0)


def _cd_sweep_kernel(x_ref, w_ref, r_ref, beta_ref, delta_ref, lam_ref, nu_ref,
                     delta_out_ref, r_out_ref):
    X = x_ref[...]                      # (N, B) resident for the whole sweep
    w = w_ref[...]
    beta = beta_ref[...]
    lam = lam_ref[0]
    nu = nu_ref[0]
    b = X.shape[1]

    # All column denominators in one MXU pass: A_j = sum_i w_i x_ij^2 + nu.
    denoms = jnp.dot(w, X * X, precision=jax.lax.Precision.HIGHEST) + nu

    def body(j, carry):
        r, delta = carry
        x = jax.lax.dynamic_slice_in_dim(X, j, 1, axis=1)[:, 0]
        A = denoms[j]
        u = delta[j]
        bj = jax.lax.dynamic_slice_in_dim(beta, j, 1)[0]
        c = jnp.dot(w * r, x, precision=jax.lax.Precision.HIGHEST) \
            + u * (A - nu) + bj * A
        s = _soft_threshold(c, lam) / A
        step = s - bj - u
        r = r - step * x
        delta = jax.lax.dynamic_update_slice_in_dim(delta, (s - bj)[None], j, 0)
        return r, delta

    r, delta = jax.lax.fori_loop(0, b, body, (r_ref[...], delta_ref[...]))
    delta_out_ref[...] = delta
    r_out_ref[...] = r


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_block_sweep(X, w, r, beta, delta, lam, nu, *, interpret=True):
    """One cyclic CD sweep over dense block X (N, B).

    lam, nu: shape-(1,) f32 arrays (AOT modules take only array args).
    -> (delta_new (B,), r_new (N,)).
    """
    n, b = X.shape
    return pl.pallas_call(
        _cd_sweep_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=interpret,
    )(X, w, r, beta, delta, lam, nu)
