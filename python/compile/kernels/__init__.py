"""Pallas kernels (L1) for d-GLMNET + their pure-numpy oracles.

All kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is the contract here; real-TPU resource
estimates live in EXPERIMENTS.md §Perf.
"""

from compile.kernels.cd_sweep import cd_block_sweep
from compile.kernels.cd_sweep_cov import cd_block_sweep_cov
from compile.kernels.line_search import line_search_grid
from compile.kernels.matvec import matvec_block
from compile.kernels.stats import logistic_stats

__all__ = [
    "cd_block_sweep",
    "cd_block_sweep_cov",
    "line_search_grid",
    "matvec_block",
    "logistic_stats",
]
