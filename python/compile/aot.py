"""AOT compiler: lower the L2 JAX functions to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every unit is lowered for a manifest of padded shapes; the rust runtime picks
the smallest compiled shape that fits and zero-pads. `artifacts/manifest.json`
describes every module (function, shape params, input/output signature) so
rust never hardcodes shapes.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Stamp-based: skips lowering when sources are older than the manifest.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32

# Padded-shape grid. N: examples per tile; B: features per block; K: alpha
# grid length for the line search. Kept deliberately small — each extra shape
# is another PJRT compile at coordinator startup.
N_SIZES = (1024, 4096, 16384, 65536)
B_SIZES = (64, 128)
K_ALPHAS = 16


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(dims, F32)


def units():
    """Yield (name, fn, example_args, meta) for every AOT unit."""
    for n in N_SIZES:
        yield (
            f"stats_n{n}",
            model.worker_stats,
            (_spec(n), _spec(n), _spec(n)),
            {"fn": "stats", "n": n},
        )
        yield (
            f"line_search_n{n}_k{K_ALPHAS}",
            model.leader_line_search,
            (_spec(n), _spec(n), _spec(n), _spec(n), _spec(K_ALPHAS)),
            {"fn": "line_search", "n": n, "k": K_ALPHAS},
        )
        for b in B_SIZES:
            yield (
                f"cd_sweep_n{n}_b{b}",
                model.worker_block_sweep,
                (_spec(n, b), _spec(n), _spec(n), _spec(b), _spec(b),
                 _spec(1), _spec(1)),
                {"fn": "cd_sweep", "n": n, "b": b},
            )
            yield (
                f"cd_sweep_cov_n{n}_b{b}",
                model.worker_block_sweep_cov,
                (_spec(n, b), _spec(n), _spec(n), _spec(b), _spec(b),
                 _spec(1), _spec(1)),
                {"fn": "cd_sweep_cov", "n": n, "b": b},
            )
            yield (
                f"matvec_n{n}_b{b}",
                model.predict_margins,
                (_spec(n, b), _spec(b), _spec(n)),
                {"fn": "matvec", "n": n, "b": b},
            )


def _sources_digest() -> str:
    """Digest of every python source that feeds the artifacts."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    paths = sorted(
        os.path.join(dp, f)
        for dp, _, fs in os.walk(root)
        for f in fs
        if f.endswith(".py")
    )
    for p in paths:
        with open(p, "rb") as fh:
            h.update(p.encode())
            h.update(fh.read())
    return h.hexdigest()


def build(out_dir: str, force: bool = False) -> int:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    digest = _sources_digest()
    if not force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as fh:
                old = json.load(fh)
            if old.get("sources_sha256") == digest and all(
                os.path.exists(os.path.join(out_dir, u["file"]))
                for u in old.get("units", [])
            ):
                print(f"artifacts up to date ({len(old['units'])} units)")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass  # stale/corrupt manifest: rebuild

    entries = []
    for name, fn, example_args, meta in units():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        out_info = lowered.out_info
        flat, _ = jax.tree_util.tree_flatten(out_info)
        entries.append(
            {
                "name": name,
                "file": fname,
                **meta,
                "inputs": [list(a.shape) for a in example_args],
                "outputs": [list(o.shape) for o in flat],
            }
        )
        print(f"lowered {name}: {len(text)} chars, outputs {entries[-1]['outputs']}")

    with open(manifest_path, "w") as fh:
        json.dump(
            {
                "version": 1,
                "sources_sha256": digest,
                "n_sizes": list(N_SIZES),
                "b_sizes": list(B_SIZES),
                "k_alphas": K_ALPHAS,
                "units": entries,
            },
            fh,
            indent=2,
        )
    print(f"wrote {manifest_path} ({len(entries)} units)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    return build(args.out_dir, force=args.force)


if __name__ == "__main__":
    sys.exit(main())
