"""L2: the JAX compute graph for d-GLMNET, composed from the L1 Pallas kernels.

These are the four AOT units the rust coordinator executes on its hot path
(via PJRT, after `aot.py` lowers them to HLO text). Everything is f32 and
fixed-shape; the rust runtime zero-pads to the nearest compiled shape
(padding rows carry mask = 0 => w = 0 => mathematically inert; padding
columns are all-zero => their coordinate updates are exactly 0).

Scalars (lam, nu) travel as shape-(1,) arrays: AOT modules take only arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import (
    cd_block_sweep,
    cd_block_sweep_cov,
    line_search_grid,
    logistic_stats,
    matvec_block,
)


def worker_stats(margins, y, mask):
    """Per-iteration worker prologue: (w, z, loss_sum).

    One fused elementwise pass over the examples (paper eq. (4)); the loss
    sum comes along for free and seeds the line-search bookkeeping.
    """
    return logistic_stats(margins, y, mask)


def worker_block_sweep(X, w, r, beta, delta, lam, nu):
    """One cyclic CD sweep over a dense (N, B) feature block (paper Alg 2).

    Carries the working residual r = z - dbeta.x across the worker's blocks;
    rust threads the returned r into the next block's call.
    """
    return cd_block_sweep(X, w, r, beta, delta, lam, nu)


def worker_block_sweep_cov(X, w, r, beta, delta, lam, nu):
    """Covariance-update variant of the sweep (EXPERIMENTS.md §Perf): same
    contract, O(B²) serial work instead of O(N·B) — the production unit."""
    return cd_block_sweep_cov(X, w, r, beta, delta, lam, nu)


def leader_line_search(margins, dmargins, y, mask, alphas):
    """Loss part of f(beta + alpha dbeta) for a grid of alphas (paper Alg 3).

    O(n) state only — the paper's reason the line search fits one machine.
    """
    return line_search_grid(margins, dmargins, y, mask, alphas)


def predict_margins(X, v, acc):
    """acc + X @ v over a dense block — margin rebuilds and test prediction."""
    return matvec_block(X, v, acc)


# ---------------------------------------------------------------------------
# Python-side composition helpers (tests / oracles only — never AOT'd).
# ---------------------------------------------------------------------------

def full_objective(margins, y, mask, beta, lam):
    """f(beta) = masked logloss(margins) + lam * ||beta||_1 (paper eq. (2))."""
    t = -y * margins
    loss = jnp.maximum(t, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(t)))
    return jnp.sum(loss * mask) + lam * jnp.sum(jnp.abs(beta))


def single_machine_iteration(X, y, mask, beta, lam, nu, block=64):
    """One full d-GLMNET outer iteration with M = 1 on a dense X — the
    python oracle used by tests to pin down the exact sequence of kernel
    calls the rust coordinator makes.

    Returns (delta, dmargins, loss_before).
    """
    margins = X @ beta
    w, z, loss = worker_stats(margins, y, mask)
    n, p = X.shape
    r = z
    delta = jnp.zeros_like(beta)
    lam_a = jnp.array([lam], jnp.float32)
    nu_a = jnp.array([nu], jnp.float32)
    for start in range(0, p, block):
        stop = min(start + block, p)
        width = stop - start
        Xb = X[:, start:stop]
        if width < block:  # pad the ragged tail block with zero columns
            Xb = jnp.pad(Xb, ((0, 0), (0, block - width)))
        beta_b = jnp.pad(beta[start:stop], (0, block - width))
        delta_b = jnp.pad(delta[start:stop], (0, block - width))
        d_new, r = worker_block_sweep(Xb, w, r, beta_b, delta_b, lam_a, nu_a)
        delta = delta.at[start:stop].set(d_new[:width])
    dmargins = z - r
    return delta, dmargins, loss[0]
