"""The covariance-update sweep must be numerically equivalent to both the
naive Pallas sweep and the float64 oracle — the §Perf optimization cannot
change the math."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import cd_block_sweep, cd_block_sweep_cov
from compile.kernels import ref


@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([16, 128, 500]),
    b=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
    lam=st.floats(0.0, 5.0),
)
def test_cov_sweep_matches_oracle(n, b, seed, lam):
    rng = np.random.default_rng(seed)
    nu = 1e-6
    X = rng.normal(size=(n, b)).astype(np.float32)
    margins = (0.5 * rng.normal(size=n)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    mask = np.ones(n, np.float32)
    w_r, z_r, _ = ref.ref_logistic_stats(margins, y, mask)
    beta = (rng.normal(size=b) * (rng.random(b) < 0.5)).astype(np.float32)

    d_cov, r_cov = cd_block_sweep_cov(
        jnp.array(X), jnp.array(w_r.astype(np.float32)),
        jnp.array(z_r.astype(np.float32)), jnp.array(beta),
        jnp.zeros(b, jnp.float32), jnp.array([lam], jnp.float32),
        jnp.array([nu], jnp.float32))
    d_ref, r_ref = ref.ref_cd_block_sweep(X, w_r, z_r, beta, np.zeros(b), lam, nu)
    np.testing.assert_allclose(np.asarray(d_cov), d_ref, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(r_cov), r_ref, rtol=5e-3, atol=5e-3)


def test_cov_and_naive_agree_bitwise_tolerance():
    rng = np.random.default_rng(9)
    n, b = 300, 32
    X = rng.normal(size=(n, b)).astype(np.float32)
    w = (0.25 * rng.random(n)).astype(np.float32)
    r = rng.normal(size=n).astype(np.float32)
    beta = rng.normal(size=b).astype(np.float32)
    args = (jnp.array(X), jnp.array(w), jnp.array(r), jnp.array(beta),
            jnp.zeros(b, jnp.float32), jnp.array([0.3], jnp.float32),
            jnp.array([1e-6], jnp.float32))
    d1, r1 = cd_block_sweep(*args)
    d2, r2 = cd_block_sweep_cov(*args)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=2e-3, atol=2e-3)


def test_cov_sweep_nonzero_delta_in_carries():
    """delta_in != 0 (multi-cycle contract) must be honored identically."""
    rng = np.random.default_rng(11)
    n, b = 200, 8
    X = rng.normal(size=(n, b)).astype(np.float32)
    w = (0.25 * np.ones(n)).astype(np.float32)
    beta = rng.normal(size=b).astype(np.float32)
    delta_in = (0.1 * rng.normal(size=b)).astype(np.float32)
    # r consistent with delta_in: r = z - X @ delta_in
    z = rng.normal(size=n).astype(np.float32)
    r = z - X @ delta_in
    args = (jnp.array(X), jnp.array(w), jnp.array(r), jnp.array(beta),
            jnp.array(delta_in), jnp.array([0.2], jnp.float32),
            jnp.array([1e-6], jnp.float32))
    d1, r1 = cd_block_sweep(*args)
    d2, r2 = cd_block_sweep_cov(*args)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=2e-3, atol=2e-3)


def test_cov_zero_columns_stay_zero():
    rng = np.random.default_rng(12)
    n, b = 64, 16
    X = rng.normal(size=(n, b)).astype(np.float32)
    X[:, 10:] = 0.0
    w = (0.25 * np.ones(n)).astype(np.float32)
    r = rng.normal(size=n).astype(np.float32)
    d, _ = cd_block_sweep_cov(
        jnp.array(X), jnp.array(w), jnp.array(r),
        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.float32),
        jnp.array([0.1], jnp.float32), jnp.array([1e-6], jnp.float32))
    assert np.all(np.asarray(d)[10:] == 0.0)
