"""Pallas kernels vs the pure-numpy oracle (ref.py) — the CORE correctness
signal of the L1 layer. Hypothesis sweeps shapes, seeds and padding patterns.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    cd_block_sweep,
    line_search_grid,
    logistic_stats,
    matvec_block,
)
from compile.kernels import ref

import jax.numpy as jnp

RTOL = 2e-4
ATOL = 2e-4


def _rng(seed):
    return np.random.default_rng(seed)


def make_problem(rng, n, b, density=1.0, pad_rows=0):
    X = rng.normal(size=(n, b)).astype(np.float32)
    if density < 1.0:
        X *= rng.random(size=(n, b)) < density
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    mask = np.ones(n, dtype=np.float32)
    if pad_rows:
        mask[n - pad_rows:] = 0.0
        X[n - pad_rows:] = 0.0
    margins = (0.5 * rng.normal(size=n)).astype(np.float32)
    return X, y, mask, margins


# ---------------------------------------------------------------------- stats

@settings(deadline=None, max_examples=25)
@given(
    n=st.sampled_from([8, 64, 257, 1024]),
    seed=st.integers(0, 2**31 - 1),
    pad_frac=st.floats(0.0, 0.5),
)
def test_stats_matches_ref(n, seed, pad_frac):
    rng = _rng(seed)
    _, y, mask, margins = make_problem(rng, n, 1, pad_rows=int(n * pad_frac))
    w, z, loss = logistic_stats(jnp.array(margins), jnp.array(y), jnp.array(mask))
    w_r, z_r, loss_r = ref.ref_logistic_stats(margins, y, mask)
    np.testing.assert_allclose(np.asarray(w), w_r, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), z_r, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(float(loss[0]), loss_r, rtol=1e-3)


def test_stats_extreme_margins_are_finite():
    margins = np.array([-40.0, -5.0, 0.0, 5.0, 40.0], dtype=np.float32)
    y = np.array([1.0, -1.0, 1.0, -1.0, 1.0], dtype=np.float32)
    mask = np.ones(5, dtype=np.float32)
    w, z, loss = logistic_stats(jnp.array(margins), jnp.array(y), jnp.array(mask))
    assert np.all(np.isfinite(np.asarray(w)))
    assert np.all(np.isfinite(np.asarray(z)))
    assert np.isfinite(float(loss[0]))


def test_stats_masked_rows_zeroed():
    n = 32
    rng = _rng(0)
    _, y, mask, margins = make_problem(rng, n, 1, pad_rows=16)
    w, z, _ = logistic_stats(jnp.array(margins), jnp.array(y), jnp.array(mask))
    assert np.all(np.asarray(w)[16:] == 0.0)
    assert np.all(np.asarray(z)[16:] == 0.0)


# ------------------------------------------------------------------- cd sweep

@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([16, 128, 500]),
    b=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
    lam=st.floats(0.0, 5.0),
    density=st.sampled_from([1.0, 0.3]),
)
def test_cd_sweep_matches_ref(n, b, seed, lam, density):
    rng = _rng(seed)
    nu = 1e-6
    X, y, mask, margins = make_problem(rng, n, b, density=density)
    w_r, z_r, _ = ref.ref_logistic_stats(margins, y, mask)
    w = w_r.astype(np.float32)
    r0 = z_r.astype(np.float32)
    beta = (rng.normal(size=b) * (rng.random(size=b) < 0.5)).astype(np.float32)
    delta0 = np.zeros(b, dtype=np.float32)

    d_k, r_k = cd_block_sweep(
        jnp.array(X), jnp.array(w), jnp.array(r0), jnp.array(beta),
        jnp.array(delta0), jnp.array([lam], jnp.float32),
        jnp.array([nu], jnp.float32),
    )
    d_ref, r_ref = ref.ref_cd_block_sweep(X, w, r0, beta, delta0, lam, nu)
    np.testing.assert_allclose(np.asarray(d_k), d_ref, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(r_k), r_ref, rtol=5e-3, atol=5e-3)


def test_cd_sweep_zero_columns_stay_zero():
    """Padding columns (all-zero) must produce exactly zero updates."""
    rng = _rng(7)
    n, b = 64, 16
    X, y, mask, margins = make_problem(rng, n, b)
    X[:, 10:] = 0.0
    w_r, z_r, _ = ref.ref_logistic_stats(margins, y, mask)
    d, _ = cd_block_sweep(
        jnp.array(X), jnp.array(w_r.astype(np.float32)),
        jnp.array(z_r.astype(np.float32)),
        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.float32),
        jnp.array([0.1], jnp.float32), jnp.array([1e-6], jnp.float32),
    )
    assert np.all(np.asarray(d)[10:] == 0.0)


def test_cd_sweep_large_lambda_gives_all_zero():
    """lam > |num| for every coordinate => full shrinkage (from beta = 0)."""
    rng = _rng(3)
    n, b = 128, 8
    X, y, mask, margins = make_problem(rng, n, b)
    w_r, z_r, _ = ref.ref_logistic_stats(np.zeros(n, np.float32), y, mask)
    lam = 1e6
    d, _ = cd_block_sweep(
        jnp.array(X), jnp.array(w_r.astype(np.float32)),
        jnp.array(z_r.astype(np.float32)),
        jnp.zeros(b, jnp.float32), jnp.zeros(b, jnp.float32),
        jnp.array([lam], jnp.float32), jnp.array([1e-6], jnp.float32),
    )
    assert np.all(np.asarray(d) == 0.0)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_cd_sweep_never_increases_quadratic_objective(seed):
    """Each sweep is exact coordinate minimization => the quadratic subproblem
    objective is non-increasing (paper Alg 2 invariant)."""
    rng = _rng(seed)
    n, b = 100, 12
    nu, lam = 1e-6, 0.3
    X, y, mask, margins = make_problem(rng, n, b)
    w_r, z_r, _ = ref.ref_logistic_stats(margins, y, mask)
    beta = rng.normal(size=b).astype(np.float32)
    before = ref.ref_full_quadratic_objective(X, w_r, z_r, beta, np.zeros(b), lam, nu)
    d, _ = cd_block_sweep(
        jnp.array(X), jnp.array(w_r.astype(np.float32)),
        jnp.array(z_r.astype(np.float32)), jnp.array(beta),
        jnp.zeros(b, jnp.float32),
        jnp.array([lam], jnp.float32), jnp.array([nu], jnp.float32),
    )
    after = ref.ref_full_quadratic_objective(
        X, w_r, z_r, beta, np.asarray(d, dtype=np.float64), lam, nu)
    assert after <= before + 1e-4 * (1.0 + abs(before))


def test_cd_sweep_carries_residual_across_blocks():
    """Splitting 2B features into two sequential block calls must equal one
    call on the concatenated block (the rust worker relies on this)."""
    rng = _rng(11)
    n, b = 96, 8
    X, y, mask, margins = make_problem(rng, n, 2 * b)
    w_r, z_r, _ = ref.ref_logistic_stats(margins, y, mask)
    w = jnp.array(w_r.astype(np.float32))
    lam = jnp.array([0.2], jnp.float32)
    nu = jnp.array([1e-6], jnp.float32)
    beta = rng.normal(size=2 * b).astype(np.float32)

    d_full, r_full = cd_block_sweep(
        jnp.array(X), w, jnp.array(z_r.astype(np.float32)),
        jnp.array(beta), jnp.zeros(2 * b, jnp.float32), lam, nu)

    d1, r_mid = cd_block_sweep(
        jnp.array(X[:, :b]), w, jnp.array(z_r.astype(np.float32)),
        jnp.array(beta[:b]), jnp.zeros(b, jnp.float32), lam, nu)
    d2, r_end = cd_block_sweep(
        jnp.array(X[:, b:]), w, r_mid,
        jnp.array(beta[b:]), jnp.zeros(b, jnp.float32), lam, nu)

    np.testing.assert_allclose(
        np.concatenate([np.asarray(d1), np.asarray(d2)]), np.asarray(d_full),
        rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_end), np.asarray(r_full),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- line search

@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([8, 255, 1024]),
    k=st.sampled_from([1, 5, 16]),
    seed=st.integers(0, 2**31 - 1),
    pad_frac=st.floats(0.0, 0.5),
)
def test_line_search_matches_ref(n, k, seed, pad_frac):
    rng = _rng(seed)
    _, y, mask, margins = make_problem(rng, n, 1, pad_rows=int(n * pad_frac))
    dm = rng.normal(size=n).astype(np.float32) * mask
    alphas = np.linspace(0.0, 1.0, k).astype(np.float32)
    got = line_search_grid(
        jnp.array(margins), jnp.array(dm), jnp.array(y), jnp.array(mask),
        jnp.array(alphas))
    want = ref.ref_line_search_grid(margins, dm, y, mask, alphas)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3)


def test_line_search_alpha0_equals_current_loss():
    rng = _rng(5)
    n = 200
    _, y, mask, margins = make_problem(rng, n, 1)
    dm = rng.normal(size=n).astype(np.float32)
    _, _, loss = logistic_stats(jnp.array(margins), jnp.array(y), jnp.array(mask))
    ls = line_search_grid(
        jnp.array(margins), jnp.array(dm), jnp.array(y), jnp.array(mask),
        jnp.array([0.0], jnp.float32))
    np.testing.assert_allclose(float(ls[0]), float(loss[0]), rtol=1e-5)


# --------------------------------------------------------------------- matvec

@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([8, 100, 512]),
    b=st.sampled_from([4, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(n, b, seed):
    rng = _rng(seed)
    X = rng.normal(size=(n, b)).astype(np.float32)
    v = rng.normal(size=b).astype(np.float32)
    acc = rng.normal(size=n).astype(np.float32)
    got = matvec_block(jnp.array(X), jnp.array(v), jnp.array(acc))
    want = ref.ref_matvec(X, v) + acc
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)
