"""L2 model-composition tests: the single-machine oracle iteration must agree
with an independent dense-numpy implementation of one GLMNET outer step, and
the building blocks must compose the way the rust coordinator composes them.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _iteration_oracle(X, y, mask, beta, lam, nu):
    """Dense float64 single-machine GLMNET step, fully independent code."""
    margins = X.astype(np.float64) @ beta.astype(np.float64)
    w, z, loss = ref.ref_logistic_stats(margins, y, mask)
    p = X.shape[1]
    delta, r = ref.ref_cd_block_sweep(X, w, z, beta, np.zeros(p), lam, nu)
    return delta, z - r, loss


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**31 - 1), lam=st.floats(0.01, 2.0))
def test_single_machine_iteration_matches_dense_oracle(seed, lam):
    rng = np.random.default_rng(seed)
    n, p = 120, 20  # p < block so one padded block; also exercises ragged pad
    nu = 1e-6
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    mask = np.ones(n, dtype=np.float32)
    beta = (rng.normal(size=p) * (rng.random(p) < 0.3)).astype(np.float32)

    d, dm, loss = model.single_machine_iteration(
        jnp.array(X), jnp.array(y), jnp.array(mask), jnp.array(beta), lam, nu)
    d_ref, dm_ref, loss_ref = _iteration_oracle(X, y, mask, beta, lam, nu)

    np.testing.assert_allclose(np.asarray(d), d_ref, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(dm), dm_ref, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(float(loss), loss_ref, rtol=1e-3)


def test_full_objective_matches_ref():
    rng = np.random.default_rng(1)
    n, p = 64, 10
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    beta = rng.normal(size=p).astype(np.float32)
    mask = np.ones(n, np.float32)
    margins = X @ beta
    lam = 0.7
    got = float(model.full_objective(
        jnp.array(margins), jnp.array(y), jnp.array(mask), jnp.array(beta), lam))
    _, _, loss = ref.ref_logistic_stats(margins, y, mask)
    want = loss + lam * np.abs(beta).sum()
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_iteration_decreases_objective_with_alpha_one_on_easy_problem():
    """On a well-conditioned problem the pure Newton step (alpha=1) must
    decrease f — the 'sufficient decrease' fast path of Alg 3 step 1."""
    rng = np.random.default_rng(42)
    n, p = 400, 8
    X = rng.normal(size=(n, p)).astype(np.float32)
    true_beta = np.zeros(p, np.float32)
    true_beta[:3] = [1.5, -2.0, 0.7]
    y = np.sign(X @ true_beta + 0.1 * rng.normal(size=n)).astype(np.float32)
    y[y == 0] = 1.0
    mask = np.ones(n, np.float32)
    beta = np.zeros(p, np.float32)
    lam, nu = 1.0, 1e-6

    d, dm, _ = model.single_machine_iteration(
        jnp.array(X), jnp.array(y), jnp.array(mask), jnp.array(beta), lam, nu)
    margins = X @ beta
    f0 = float(model.full_objective(
        jnp.array(margins), jnp.array(y), jnp.array(mask), jnp.array(beta), lam))
    f1 = float(model.full_objective(
        jnp.array(margins) + jnp.asarray(dm), jnp.array(y), jnp.array(mask),
        jnp.array(beta) + jnp.asarray(d), lam))
    assert f1 < f0
