"""AOT pipeline tests: every unit lowers to HLO text that (a) is non-empty
and parseable-looking, (b) matches the manifest signature, and (c) the
manifest covers the full (fn x shape) grid the rust runtime expects.
"""

import json
import os
import tempfile

import pytest

from compile import aot


def test_units_cover_shape_grid():
    names = {name for name, *_ in aot.units()}
    for n in aot.N_SIZES:
        assert f"stats_n{n}" in names
        assert f"line_search_n{n}_k{aot.K_ALPHAS}" in names
        for b in aot.B_SIZES:
            assert f"cd_sweep_n{n}_b{b}" in names
            assert f"cd_sweep_cov_n{n}_b{b}" in names
            assert f"matvec_n{n}_b{b}" in names


def test_lower_one_unit_to_hlo_text():
    # smallest cd_sweep: the structurally richest unit (fori_loop -> while)
    import jax
    name, fn, args, meta = next(
        u for u in aot.units() if u[0] == "cd_sweep_n1024_b64")
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    assert "while" in text  # the sweep's sequential column loop survives
    assert len(text) > 1000


def test_build_writes_manifest_and_is_idempotent(tmp_path):
    out = str(tmp_path / "artifacts")
    # restrict the grid for test speed
    old_n, old_b = aot.N_SIZES, aot.B_SIZES
    aot.N_SIZES, aot.B_SIZES = (1024,), (64,)
    try:
        assert aot.build(out) == 0
        manifest = json.load(open(os.path.join(out, "manifest.json")))
        assert manifest["version"] == 1
        assert len(manifest["units"]) == 5
        for u in manifest["units"]:
            p = os.path.join(out, u["file"])
            assert os.path.exists(p)
            assert "HloModule" in open(p).read(200)
        mtime = os.path.getmtime(os.path.join(out, "manifest.json"))
        assert aot.build(out) == 0  # second run: stamp hit, no rewrite
        assert os.path.getmtime(os.path.join(out, "manifest.json")) == mtime
    finally:
        aot.N_SIZES, aot.B_SIZES = old_n, old_b


def test_manifest_signatures_match_lowering(tmp_path):
    """Output arities recorded in the manifest must match what rust unpacks:
    stats -> 3 outputs, cd_sweep/cd_sweep_cov -> 2, line_search/matvec -> 1."""
    out = str(tmp_path / "artifacts")
    old_n, old_b = aot.N_SIZES, aot.B_SIZES
    aot.N_SIZES, aot.B_SIZES = (1024,), (64,)
    try:
        aot.build(out)
        manifest = json.load(open(os.path.join(out, "manifest.json")))
        arity = {u["fn"]: len(u["outputs"]) for u in manifest["units"]}
        assert arity == {
            "stats": 3,
            "cd_sweep": 2,
            "cd_sweep_cov": 2,
            "line_search": 1,
            "matvec": 1,
        }
    finally:
        aot.N_SIZES, aot.B_SIZES = old_n, old_b
