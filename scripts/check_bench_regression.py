#!/usr/bin/env python3
"""Diff a fresh BENCH_*.json against the committed baseline.

Usage: check_bench_regression.py FRESH BASELINE

Fails (exit 1) when:
  * any timing entry's median regresses by more than MAX_TIME_REGRESSION
    (15%) relative to the baseline, or
  * a timing entry that also records `p99_secs` (the serve latency
    benches) sees its tail regress by more than MAX_TAIL_REGRESSION
    (50% — tails are noisier than medians on shared runners), or
  * any comm-bytes counter grows at all (the sparse wire format must never
    get chattier). For entries that record a `chosen_strategy` (the
    per-exchange-strategy section), only the strategy the cost model
    actually picked — plus the `auto_` path itself — is gated; the
    non-chosen strategy's bytes are informational, or
  * any `*peak_rss_bytes` counter grows by more than MAX_RSS_REGRESSION
    (25%) — the leader-memory canary of the out-of-core data plane, or
  * any `*_speedup_x` ratio (the sweep-kernel ablation in
    BENCH_ablation.json) erodes by more than MAX_SPEEDUP_EROSION (25%)
    relative to the baseline — a kernel win must not quietly rot, or
  * the tree topology's `leader_byte_ratio_m8_over_m4_tree` exceeds
    MAX_TREE_LEADER_RATIO (1.15) — an *absolute* gate, checked even in
    bootstrap mode: the peer-to-peer tree's leader bytes per iteration
    must stay independent of M (the star's ratio sits near 2 and is
    informational only).

Bootstrap mode: when BASELINE does not exist yet, prints instructions and
exits 0 (absolute gates still apply) — commit the fresh file as the
baseline to arm the relative gates.
"""

import json
import sys

MAX_TIME_REGRESSION = 0.15
# p99 tails wobble far more than medians on shared runners; gate loosely
MAX_TAIL_REGRESSION = 0.50
# peak RSS wobbles with allocator behaviour on shared runners; gate growth
# beyond this factor (a leader re-growing an O(nnz) X copy blows well past it)
MAX_RSS_REGRESSION = 0.25
# timings below this are noise-dominated on shared CI runners
MIN_COMPARABLE_SECS = 50e-6
# speedup ratios (cov vs naive, threaded vs serial) may shrink this much
# before the gate trips — they are ratios of two noisy medians
MAX_SPEEDUP_EROSION = 0.25
# absolute ceiling on the tree topology's leader-byte M-scaling: per-fit
# admission traffic is O(M) but amortizes over the iterations, so the
# measured M=8 / M=4 per-iteration ratio sits near 1.0 when the leader's
# data plane is truly pinned to the root edge
MAX_TREE_LEADER_RATIO = 1.15


def tree_leader_failures(fresh):
    """Absolute (baseline-free) gate on the tree leader-byte M-ratio."""
    out = []
    for name, entry in sorted(fresh.items()):
        if not isinstance(entry, dict):
            continue
        ratio = entry.get("leader_byte_ratio_m8_over_m4_tree")
        if ratio is None:
            continue
        if ratio > MAX_TREE_LEADER_RATIO:
            out.append(
                f"{name}.leader_byte_ratio_m8_over_m4_tree: {ratio:.2f}x > "
                f"{MAX_TREE_LEADER_RATIO:.2f}x (tree leader bytes must be O(1) in M)")
        else:
            print(f"  [ok]     {name}.leader_byte_ratio_m8_over_m4_tree: "
                  f"{ratio:.2f}x <= {MAX_TREE_LEADER_RATIO:.2f}x")
    return out


def load(path):
    with open(path) as f:
        return json.load(f)["results"]


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    fresh_path, baseline_path = sys.argv[1], sys.argv[2]
    fresh = load(fresh_path)
    absolute = tree_leader_failures(fresh)
    try:
        baseline = load(baseline_path)
    except FileNotFoundError:
        print(f"no committed baseline at {baseline_path} — bootstrap mode.")
        print(f"to arm the regression gate:  cp {fresh_path} {baseline_path}  and commit it.")
        if absolute:
            print(f"\n{len(absolute)} absolute-gate failure(s):")
            for f in absolute:
                print(f"  FAIL  {f}")
            return 1
        return 0

    failures = absolute
    compared = 0
    for name, base in sorted(baseline.items()):
        cur = fresh.get(name)
        if cur is None:
            print(f"  [gone]   {name} (baseline entry missing from fresh run)")
            continue
        if isinstance(base, dict) and "median_secs" in base:
            b, c = base["median_secs"], cur["median_secs"]
            compared += 1
            if b >= MIN_COMPARABLE_SECS and c > b * (1 + MAX_TIME_REGRESSION):
                failures.append(f"{name}: median {c:.6g}s vs baseline {b:.6g}s "
                                f"(+{(c / b - 1) * 100:.1f}% > {MAX_TIME_REGRESSION * 100:.0f}%)")
            else:
                print(f"  [ok]     {name}: {c:.6g}s vs {b:.6g}s")
            if "p99_secs" in base and "p99_secs" in cur:
                tb, tc = base["p99_secs"], cur["p99_secs"]
                compared += 1
                if tb >= MIN_COMPARABLE_SECS and tc > tb * (1 + MAX_TAIL_REGRESSION):
                    failures.append(
                        f"{name}: p99 {tc:.6g}s vs baseline {tb:.6g}s "
                        f"(+{(tc / tb - 1) * 100:.1f}% > {MAX_TAIL_REGRESSION * 100:.0f}%)")
                else:
                    print(f"  [ok]     {name}: p99 {tc:.6g}s vs {tb:.6g}s")
        elif isinstance(base, dict):
            # nested counters (e.g. fit_sparse_vs_dense_comm): any *comm_bytes
            # growth fails. Strategy entries gate only the cost-model pick.
            chosen = cur.get("chosen_strategy")
            gated = None
            if chosen is not None:
                gated = {f"{chosen}_comm_bytes", "auto_comm_bytes"}
            for key, bval in sorted(base.items()):
                if isinstance(bval, dict) and "median_secs" in bval:
                    # a timing entry nested one level down (the families
                    # section of BENCH_ablation.json): same median gate as
                    # top-level timings
                    cval = cur.get(key)
                    if not isinstance(cval, dict) or "median_secs" not in cval:
                        continue
                    b, c = bval["median_secs"], cval["median_secs"]
                    compared += 1
                    if b >= MIN_COMPARABLE_SECS and c > b * (1 + MAX_TIME_REGRESSION):
                        failures.append(
                            f"{name}.{key}: median {c:.6g}s vs baseline {b:.6g}s "
                            f"(+{(c / b - 1) * 100:.1f}% > "
                            f"{MAX_TIME_REGRESSION * 100:.0f}%)")
                    else:
                        print(f"  [ok]     {name}.{key}: {c:.6g}s vs {b:.6g}s")
                    continue
                if key.endswith("peak_rss_bytes"):
                    cval = cur.get(key)
                    if cval is None or bval <= 0:
                        continue
                    compared += 1
                    if cval > bval * (1 + MAX_RSS_REGRESSION):
                        failures.append(
                            f"{name}.{key}: {cval:.0f} bytes vs baseline {bval:.0f} "
                            f"(+{(cval / bval - 1) * 100:.1f}% > "
                            f"{MAX_RSS_REGRESSION * 100:.0f}% — is the leader "
                            f"holding X again?)")
                    else:
                        print(f"  [ok]     {name}.{key}: {cval:.0f} vs {bval:.0f} bytes")
                    continue
                if key.endswith("_speedup_x"):
                    cval = cur.get(key)
                    if cval is None or bval <= 0:
                        continue
                    compared += 1
                    if cval < bval * (1 - MAX_SPEEDUP_EROSION):
                        failures.append(
                            f"{name}.{key}: {cval:.2f}x vs baseline {bval:.2f}x "
                            f"({(1 - cval / bval) * 100:.1f}% erosion > "
                            f"{MAX_SPEEDUP_EROSION * 100:.0f}%)")
                    else:
                        print(f"  [ok]     {name}.{key}: {cval:.2f}x vs {bval:.2f}x")
                    continue
                if not key.endswith("comm_bytes"):
                    continue
                cval = cur.get(key)
                if cval is None:
                    continue
                if gated is not None and key not in gated:
                    print(f"  [info]   {name}.{key}: {cval:.0f} bytes "
                          f"(not the chosen strategy, ungated)")
                    continue
                compared += 1
                if cval > bval:
                    failures.append(f"{name}.{key}: {cval:.0f} bytes vs baseline "
                                    f"{bval:.0f} (comm traffic must not grow)")
                else:
                    print(f"  [ok]     {name}.{key}: {cval:.0f} <= {bval:.0f} bytes")

    print(f"\ncompared {compared} entries against {baseline_path}")
    if failures:
        print(f"\n{len(failures)} regression(s):")
        for f in failures:
            print(f"  FAIL  {f}")
        return 1
    print("no regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
