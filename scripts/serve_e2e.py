#!/usr/bin/env python3
"""End-to-end exercise of `dglmnet serve` against a freshly trained model.

Drives the full artifact lifecycle with nothing but the Python stdlib:

  1. generate a dna-shaped dataset and train two models (different λ)
  2. score the dataset offline with `dglmnet predict` (twice — the output
     must be byte-deterministic) for both models
  3. start `dglmnet serve` on an ephemeral port and wait for `serve_ready`
  4. single `/predict` and streamed `/predict_batch` responses must match
     the offline ndjson *byte for byte* (same shared scoring kernel)
  5. malformed requests get a 4xx, never a hang
  6. hot-swap: while 4 client threads hammer `/predict`, atomically replace
     the artifact; every response must be a 200 whose margin matches the
     model version it claims to be scored with — no torn reads, no drops
  7. a corrupt artifact must be skipped (old model keeps serving)
  8. after swapping back, `/predict_batch` must again bit-match offline

Usage: serve_e2e.py --bin PATH/TO/dglmnet [--workdir DIR]
"""

import argparse
import http.client
import json
import os
import re
import shutil
import subprocess
import sys
import threading
import time

POLL_SECS = 0.1
SWAP_TIMEOUT_SECS = 30


def sh(args, **kw):
    print("+", " ".join(str(a) for a in args), flush=True)
    return subprocess.run([str(a) for a in args], check=True,
                          capture_output=True, text=True, **kw)


def train(bin_path, data, lam, out):
    r = sh([bin_path, "train", "--input", data, "--kind", "dna",
            "--machines", "2", "--engine", "native", "--lambda", str(lam),
            "--max-iter", "30", "--model-out", out])
    m = re.search(r"model saved to .* \(version ([0-9a-f]{16})\)", r.stdout)
    assert m, f"train printed no model version:\n{r.stdout}"
    return m.group(1)


def predict_offline(bin_path, model, data, out):
    r = sh([bin_path, "predict", "--model", model, "--input", data])
    with open(out, "w") as f:
        f.write(r.stdout)
    return r.stdout


def libsvm_examples(path, limit):
    """First `limit` rows as /predict JSON bodies. Index/value tokens are
    passed through verbatim so the server parses the same decimal text the
    offline path read — no Python float round-trip in between."""
    examples = []
    with open(path) as f:
        for line in f:
            toks = line.split()[1:]
            idx = ",".join(t.split(":")[0] for t in toks)
            val = ",".join(t.split(":")[1] for t in toks)
            examples.append('{"indices":[%s],"values":[%s]}' % (idx, val))
            if len(examples) == limit:
                break
    return examples


class ServeProc:
    def __init__(self, bin_path, artifact):
        self.proc = subprocess.Popen(
            [bin_path, "serve", "--model", artifact,
             "--listen", "127.0.0.1:0", "--poll-interval-secs", str(POLL_SECS)],
            stdout=subprocess.PIPE, text=True)
        line = self.proc.stdout.readline()
        m = re.match(r"serve_ready addr=(\S+) model_version=([0-9a-f]{16})", line)
        assert m, f"no serve_ready line, got: {line!r}"
        self.addr, self.version = m.group(1), m.group(2)
        print(f"serve up at {self.addr} (version {self.version})", flush=True)

    def stop(self):
        self.proc.terminate()
        self.proc.wait(timeout=10)


def request(addr, method, path, body=None):
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=10)
    try:
        conn.request(method, path, body=body)
        resp = conn.getresponse()
        return resp.status, resp.read(), dict(resp.getheaders())
    finally:
        conn.close()


def healthz_version(addr):
    status, body, _ = request(addr, "GET", "/healthz")
    assert status == 200, f"/healthz -> {status}"
    return json.loads(body)["model_version"]


def wait_for_version(addr, want, why):
    deadline = time.monotonic() + SWAP_TIMEOUT_SECS
    while time.monotonic() < deadline:
        if healthz_version(addr) == want:
            return
        time.sleep(POLL_SECS / 2)
    sys.exit(f"FAIL: server never served version {want} ({why})")


def atomic_replace(src, dst):
    tmp = dst + ".tmp"
    shutil.copyfile(src, tmp)
    os.replace(tmp, dst)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", required=True)
    ap.add_argument("--workdir", default="serve_e2e_work")
    args = ap.parse_args()
    bin_path = os.path.abspath(args.bin)
    os.makedirs(args.workdir, exist_ok=True)
    os.chdir(args.workdir)

    sh([bin_path, "gen-data", "--kind", "dna", "--examples", "2000",
        "--features", "200", "--nnz-per-row", "8", "--seed", "3",
        "--out", "data.svm"])
    version_a = train(bin_path, "data.svm", 0.5, "model_a.artifact")
    version_b = train(bin_path, "data.svm", 0.25, "model_b.artifact")
    assert version_a != version_b, "the two λ must give distinct models"

    # offline scoring is byte-deterministic
    ndjson_a = predict_offline(bin_path, "model_a.artifact", "data.svm", "a.ndjson")
    ndjson_a2 = predict_offline(bin_path, "model_a.artifact", "data.svm", "a2.ndjson")
    assert ndjson_a == ndjson_a2, "offline predict is not deterministic"
    ndjson_b = predict_offline(bin_path, "model_b.artifact", "data.svm", "b.ndjson")
    lines_a, lines_b = ndjson_a.splitlines(), ndjson_b.splitlines()

    shutil.copyfile("model_a.artifact", "serving.artifact")
    serve = ServeProc(bin_path, "serving.artifact")
    addr = serve.addr
    assert serve.version == version_a, "served version != trained version"
    ok = True
    try:
        # --- single predict bit-matches offline line 0 -------------------
        examples = libsvm_examples("data.svm", 256)
        status, body, _ = request(addr, "POST", "/predict", examples[0])
        assert status == 200, f"/predict -> {status}: {body}"
        got, want = json.loads(body), json.loads(lines_a[0])
        assert got["margin"] == want["margin"], (got, want)
        assert got["proba"] == want["proba"], (got, want)
        assert got["model_version"] == version_a
        print("single /predict matches offline predict", flush=True)

        # --- streamed batch is byte-identical to offline ndjson ----------
        batch = '{"examples":[%s]}' % ",".join(examples)
        status, body, headers = request(addr, "POST", "/predict_batch", batch)
        assert status == 200, f"/predict_batch -> {status}"
        assert headers.get("X-Model-Version") == version_a
        assert body.decode() == "\n".join(lines_a[:256]) + "\n", \
            "batch stream differs from offline predict output"
        print("256-example /predict_batch is byte-identical to offline", flush=True)

        # --- malformed requests: 4xx, never a hang -----------------------
        for bad, want_status in [("this is not json", 400),
                                 ('{"indices":[0],"values":[1,2]}', 400),
                                 ('{"values":[1]}', 400)]:
            status, body, _ = request(addr, "POST", "/predict", bad)
            assert status == want_status, f"{bad!r} -> {status}"
            assert "error" in json.loads(body)
        status, _, _ = request(addr, "GET", "/nope")
        assert status == 404
        print("malformed requests answered with 4xx", flush=True)

        # --- hot-swap under concurrent load ------------------------------
        margin_a = json.loads(lines_a[0])["margin"]
        margin_b = json.loads(lines_b[0])["margin"]
        stop = threading.Event()
        failures, hits = [], []

        def hammer():
            count = 0
            while not stop.is_set():
                try:
                    status, body, _ = request(addr, "POST", "/predict", examples[0])
                    v = json.loads(body)
                    expected = {version_a: margin_a, version_b: margin_b}.get(
                        v.get("model_version"))
                    if status != 200 or v["margin"] != expected:
                        failures.append((status, body))
                        return
                    count += 1
                except Exception as e:  # noqa: BLE001 - any failure fails the gate
                    failures.append(("exception", repr(e)))
                    return
            hits.append(count)

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        atomic_replace("model_b.artifact", "serving.artifact")
        wait_for_version(addr, version_b, "hot-swap a -> b")
        time.sleep(0.5)  # keep hammering on the new model for a beat
        stop.set()
        for t in threads:
            t.join()
        assert not failures, f"requests failed during hot-swap: {failures[:3]}"
        total = sum(hits)
        assert total > 0, "hammer threads made no requests"
        print(f"hot-swap a->b: {total} concurrent requests, 0 failures", flush=True)

        # --- corrupt artifact is skipped; old model keeps serving --------
        with open("serving.artifact", "w") as f:
            f.write("dglmnet-model v2 p=200 n=2000 lambda=0.5 solver=x "
                    "nnz=3 checksum=0000000000000000\n0 1\n")
        time.sleep(POLL_SECS * 10)
        assert healthz_version(addr) == version_b, \
            "corrupt artifact replaced the served model"
        print("corrupt artifact rejected; old model still serving", flush=True)

        # --- swap back and re-verify the batch path ----------------------
        atomic_replace("model_a.artifact", "serving.artifact")
        wait_for_version(addr, version_a, "recovery swap b -> a")
        status, body, _ = request(addr, "POST", "/predict_batch", batch)
        assert status == 200
        assert body.decode() == "\n".join(lines_a[:256]) + "\n"
        status, body, _ = request(addr, "GET", "/metrics")
        stats = json.loads(body)
        assert stats["swaps"] >= 2, stats
        assert stats["swap_failures"] >= 1, stats
        assert stats["server_errors"] == 0, stats
        print(f"serve_e2e OK: {stats}", flush=True)
    except AssertionError as e:
        ok = False
        print(f"FAIL: {e}", file=sys.stderr, flush=True)
    finally:
        serve.stop()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
