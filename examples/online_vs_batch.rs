//! **End-to-end validation driver** (DESIGN.md §6): the paper's headline
//! experiment on one dataset — d-GLMNET's regularization path vs the
//! distributed truncated-gradient baseline's parameter grid, compared on
//! the quality-vs-sparsity plane (Figure 1) plus the Table-3-style timing
//! row. Exercises every layer: synthetic data → by-feature sharding → M
//! worker threads running the AOT Pallas kernel through PJRT → simulated
//! tree AllReduce → leader line search → metrics.
//!
//! Run: `cargo run --release --example online_vs_batch`
//!
//! Both legs run on the unified `Estimator` API: the d-GLMNET path goes
//! through the estimator-generic `RegPath` runner, and each grid combo is a
//! `DistributedOnlineEstimator` scored per pass by a `FitObserver` — the
//! head-to-head comparison has no solver-specific code paths.

use dglmnet::baselines::grid::{grid_frontier, online_grid_search};
use dglmnet::config::{EngineKind, PathConfig, TrainConfig};
use dglmnet::data::synth;
use dglmnet::report::{ascii_scatter, write_series_csv, Series};
use dglmnet::solver::{lambda_max, RegPath};

fn main() -> dglmnet::Result<()> {
    let machines = 4;
    let ds = synth::dna_like(20_000, 400, 12, 2024);
    let split = ds.split(0.8, 2024).unwrap();
    let s = split.train.summary();
    println!(
        "dataset {}: n = {} / {} test, p = {}, nnz = {} (avg {:.1}/row)",
        s.name,
        s.n_examples,
        split.test.n_examples(),
        s.n_features,
        s.nnz,
        s.avg_nonzeros
    );

    // ---- d-GLMNET path ---------------------------------------------------
    let engine = EngineKind::Auto; // per-shard XLA/native routing
    println!("\n[1/2] d-GLMNET path ({machines} machines, engine = {engine:?})");
    let cfg = TrainConfig::builder()
        .machines(machines)
        .engine(engine)
        .max_iter(40)
        .build();
    let path_cfg = PathConfig { steps: 14, ..Default::default() };
    let t0 = std::time::Instant::now();
    let path = RegPath::run(&split.train, &split.test, &cfg, &path_cfg)?;
    let dg_secs = t0.elapsed().as_secs_f64();

    // ---- online baseline grid (§4.3) --------------------------------------
    println!("[2/2] distributed truncated gradient (lr × decay × λ grid)");
    // λ ladder extended above λ_max: truncated gradient needs far stronger
    // shrinkage than the batch objective to reach the same sparsity (the
    // paper likewise added dataset-specific λ ranges for VW, §4.3).
    let lam_max = lambda_max(&split.train);
    let lambdas: Vec<f64> = (-6..=10).map(|i| lam_max * 0.5f64.powi(i)).collect();
    let t1 = std::time::Instant::now();
    let passes = 8;
    let grid = online_grid_search(
        &split.train,
        &split.test,
        machines,
        &[0.1, 0.2, 0.3, 0.4, 0.5],
        &[0.5, 0.7, 0.9],
        &lambdas,
        passes,
        3,
    );
    let vw_secs = t1.elapsed().as_secs_f64();

    // ---- Figure-1 comparison ----------------------------------------------
    let mut dg_series = Series::new("d-glmnet");
    for p in &path.points {
        if p.nnz > 0 {
            dg_series.push(p.nnz as f64, p.auprc);
        }
    }
    let mut vw_series = Series::new("trunc-grad");
    for g in &grid {
        if g.nnz > 0 {
            vw_series.push(g.nnz as f64, g.auprc);
        }
    }
    println!("\nFigure 1 analog — test AUPRC vs nnz(beta):");
    print!("{}", ascii_scatter(&[dg_series.clone(), vw_series.clone()], 70, 18));
    write_series_csv("target/online_vs_batch.csv", &[dg_series, vw_series])?;

    // frontier dominance check (the paper's Figure-1 claim)
    let dg_front = path.frontier();
    let vw_front = grid_frontier(&grid);
    let mut wins = 0usize;
    let mut total = 0usize;
    for &(nnz, auprc) in &dg_front {
        // best baseline quality at *no more* features than d-GLMNET used
        let vw_best = vw_front
            .iter()
            .filter(|&&(vnnz, _)| vnnz <= nnz)
            .map(|&(_, a)| a)
            .fold(f64::NEG_INFINITY, f64::max);
        if vw_best.is_finite() {
            total += 1;
            if auprc >= vw_best - 1e-3 {
                wins += 1;
            }
        }
    }
    println!("\nfrontier comparison (paper: d-GLMNET wins at every sparsity level):");
    println!("  d-GLMNET >= baseline at {wins}/{total} comparable sparsity levels");

    // ---- Table-3 style timing ----------------------------------------------
    println!("\nTable 3 analog:");
    println!(
        "  d-GLMNET : {} iters, {:.1}s total, {:.2}s/iter, line search {:.0}%",
        path.total_iterations,
        dg_secs,
        dg_secs / path.total_iterations.max(1) as f64,
        path.line_search_frac * 100.0
    );
    let vw_pass_count = grid.len(); // one snapshot per pass per combo
    println!(
        "  baseline : {} grid combos x {passes} passes, {:.1}s total, {:.3}s/pass",
        vw_pass_count / passes,
        vw_secs,
        vw_secs / vw_pass_count.max(1) as f64
    );
    println!("\nwrote target/online_vs_batch.csv");
    Ok(())
}
