//! Scaling ablation (A2/A4): how the machine count M affects convergence
//! (the block-diagonal Hessian gets coarser) and communication (the
//! O((n+p)·ln M) tree AllReduce cost).
//!
//! Run: `cargo run --release --example scaling_m`

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver, Estimator, NoopObserver};

fn main() -> dglmnet::Result<()> {
    let ds = synth::webspam_like(4_000, 4_000, 30, 99);
    let split = ds.split(0.8, 99).unwrap();
    let lam = lambda_max(&split.train) / 32.0;
    println!(
        "webspam-like n = {}, p = {}, lambda = {:.4}",
        split.train.n_examples(),
        split.train.n_features(),
        lam
    );
    println!("\nM     iters  objective     nnz    sim-compute(s)  sim-comm(s)  comm-bytes");

    for m in [1usize, 2, 4, 8, 16] {
        let cfg = TrainConfig::builder()
            .machines(m)
            .engine(EngineKind::Native) // apples-to-apples across M
            .lambda(lam)
            .max_iter(60)
            .build();
        let mut solver = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
        // the uniform Estimator interface — swap in any baseline estimator
        // here and the ablation loop is unchanged
        let fit = Estimator::fit(&mut solver, &split.train, &mut NoopObserver)?;
        println!(
            "{:<5} {:<6} {:<12.4}  {:<6} {:<15.4} {:<12.6} {}",
            m,
            fit.iterations,
            fit.objective,
            fit.nnz(),
            fit.sim_compute_secs,
            fit.sim_comm_secs,
            fit.comm_bytes
        );
    }
    println!(
        "\nexpected shape: objective identical across M (same optimum), iterations\n\
         grow slowly with M (coarser Hessian blocks), comm grows ~log2(M)."
    );
    Ok(())
}
