//! Quickstart: generate a small dataset, train d-GLMNET at one λ on a
//! 4-machine simulated cluster (XLA engine — the AOT Pallas hot path),
//! evaluate on held-out data.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first; falls back to the native engine if
//! artifacts are missing.)

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::synth;
use dglmnet::metrics;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

fn main() -> dglmnet::Result<()> {
    // 1. A dna-like synthetic problem: 6k examples, 200 features, short rows.
    let ds = synth::dna_like(6_000, 200, 10, 42);
    let split = ds.split(0.8, 42);
    println!(
        "dataset: {} train / {} test examples, {} features, {} nnz",
        split.train.n_examples(),
        split.test.n_examples(),
        split.train.n_features(),
        split.train.x.nnz()
    );

    // 2. Configure the simulated cluster. The XLA engine runs the AOT
    //    Pallas cd_block_sweep through PJRT inside every worker thread.
    let engine = if cfg!(feature = "xla")
        && std::path::Path::new("artifacts/manifest.json").exists()
    {
        EngineKind::Xla
    } else {
        eprintln!("xla feature/artifacts missing -> native engine (run `make artifacts`)");
        EngineKind::Native
    };
    let lam = lambda_max(&split.train) / 64.0;
    let cfg = TrainConfig::builder()
        .machines(4)
        .engine(engine)
        .lambda(lam)
        .max_iter(50)
        .verbose(true)
        .build();

    // 3. Fit.
    let mut solver = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
    let fit = solver.fit(None)?;

    // 4. Evaluate.
    let margins = fit.model.predict_margins(&split.test.x);
    println!("\n--- results @ lambda = {lam:.4} ---");
    println!("iterations     : {} (converged = {})", fit.iterations, fit.converged);
    println!("objective      : {:.4}", fit.objective);
    println!("nnz(beta)      : {}", fit.nnz());
    println!("test AUPRC     : {:.4}", metrics::auprc(&margins, &split.test.y));
    println!("test ROC-AUC   : {:.4}", metrics::roc_auc(&margins, &split.test.y));
    println!("test accuracy  : {:.4}", metrics::accuracy(&margins, &split.test.y));
    println!(
        "simulated comm : {:.4}s over {} bytes ({} machines, tree allreduce)",
        fit.sim_comm_secs, fit.comm_bytes, cfg.machines
    );
    Ok(())
}
