//! Quickstart for the unified training API: fit d-GLMNET through the
//! `Estimator` trait with a live observer, then re-run the same fit through
//! the stepwise `FitDriver` — checkpointing mid-flight and resuming from
//! the saved file — and verify both paths land on the same objective.
//!
//! Run: `cargo run --release --example quickstart`
//! (uses the native engine unless `--features xla` + `make artifacts`).

use dglmnet::config::TrainConfig;
use dglmnet::data::synth;
use dglmnet::metrics;
use dglmnet::solver::{
    lambda_max, Checkpoint, DGlmnetSolver, Estimator, FitControl, FitObserver, FitStep,
    StepOutcome,
};

/// A custom observer: print a progress line every iteration, stop early if
/// the objective stalls hard (the trait-object API the regpath/grid/bench
/// layers use for every solver).
struct Progress {
    last: Option<f64>,
}

impl FitObserver for Progress {
    fn on_iteration(&mut self, step: &FitStep<'_>) -> FitControl {
        let r = step.record;
        println!(
            "  iter {:>3}  f = {:>10.4}  alpha = {:.3}  comm = {} B",
            r.iter, r.objective, r.alpha, r.comm_bytes
        );
        let stalled = self
            .last
            .is_some_and(|prev| (prev - r.objective).abs() < 1e-12 * prev.abs());
        self.last = Some(r.objective);
        if stalled {
            FitControl::Stop
        } else {
            FitControl::Continue
        }
    }
}

fn main() -> dglmnet::Result<()> {
    // 1. A dna-like synthetic problem: 6k examples, 200 features, short rows.
    let ds = synth::dna_like(6_000, 200, 10, 42);
    let split = ds.split(0.8, 42).unwrap();
    let lam = lambda_max(&split.train) / 64.0;
    println!(
        "dataset: {} train / {} test examples, {} features; lambda = {lam:.4}",
        split.train.n_examples(),
        split.test.n_examples(),
        split.train.n_features()
    );

    // 2. One-shot fit through the Estimator trait (works identically for
    //    the shotgun / truncated-gradient / distributed-online baselines).
    let cfg = TrainConfig::builder().machines(4).lambda(lam).max_iter(50).build();
    let mut solver = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
    println!("\n[1/2] Estimator::fit with a custom observer:");
    let fit = Estimator::fit(&mut solver, &split.train, &mut Progress { last: None })?;

    let margins = fit.model.predict_margins(&split.test.x);
    println!("\n--- results ({}) ---", solver.name());
    println!("iterations     : {} (converged = {})", fit.iterations, fit.converged);
    println!("objective      : {:.4}", fit.objective);
    println!("nnz(beta)      : {}", fit.nnz());
    println!("test AUPRC     : {:.4}", metrics::auprc(&margins, &split.test.y));
    println!("test ROC-AUC   : {:.4}", metrics::roc_auc(&margins, &split.test.y));
    println!(
        "simulated comm : {:.4}s over {} bytes ({} machines, sparse tree allreduce)",
        fit.sim_comm_secs, fit.comm_bytes, cfg.machines
    );

    // 3. The same fit, stepwise: the caller owns the loop, checkpoints at
    //    iteration 5, then resumes from the file in a fresh solver — the
    //    resumed run reproduces the uninterrupted objective exactly.
    println!("\n[2/2] stepwise FitDriver with checkpoint/resume:");
    let ckpt_path = std::env::temp_dir().join("dglmnet_quickstart.ckpt.json");
    let mut first = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
    let mut driver = first.driver(lam);
    loop {
        match driver.step()? {
            StepOutcome::Progress(rec) if rec.iter == 5 => {
                driver.checkpoint()?.save(&ckpt_path)?;
                println!("  checkpoint written at iteration 5 -> {}", ckpt_path.display());
                break; // simulate the process dying here
            }
            StepOutcome::Progress(_) => {}
            StepOutcome::Finished { .. } => break,
        }
    }

    // "fresh process": a brand-new solver, state restored from the file
    let ck = Checkpoint::load(&ckpt_path)?;
    let mut resumed = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
    let fit2 = resumed.driver_from_checkpoint(&ck)?.run(&mut dglmnet::solver::NoopObserver)?;
    println!(
        "  resumed at iter {} -> finished at iter {} with f = {:.6}",
        ck.iter, fit2.iterations, fit2.objective
    );
    println!(
        "  one-shot f = {:.6}  |Δ| = {:.2e}",
        fit.objective,
        (fit.objective - fit2.objective).abs()
    );
    std::fs::remove_file(&ckpt_path).ok();
    Ok(())
}
