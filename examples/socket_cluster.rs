//! A genuinely multi-process d-GLMNET fit over the socket transport — in
//! two acts.
//!
//! **Act 1 (data flags):** the leader process binds an ephemeral TCP port
//! and re-executes *itself* twice with `worker <machine> <addr>` arguments
//! — two real OS processes, each rebuilding its feature shard
//! deterministically from the same synthetic dataset, connecting back, and
//! serving the node protocol.
//!
//! **Act 2 (sharded store):** the leader writes the dataset into an
//! on-disk [`ShardStore`] (`manifest.json` + one by-feature shard file per
//! machine + `y.bin`) and re-executes itself with
//! `worker-store <machine> <addr> <dir>` arguments. Each worker process
//! now reads **only its own shard file**, and the store-driven leader
//! (`from_store_socket`) holds nothing but `y`, β and the margins — it
//! never constructs a matrix of X. This is the paper's "dataset cannot fit
//! one machine" deployment made physical; the leader prints its peak RSS
//! so you can see the O(n) footprint.
//!
//! Both acts assert bit-identical trajectories (objective, β, and the
//! comm-bytes ledger) against the in-process run — the property the CI
//! socket jobs gate on.
//!
//! Run: `cargo run --release --example socket_cluster`
//!
//! Production deployments use the `dglmnet shard` / `dglmnet worker
//! --store` CLI subcommands instead of the self-exec trick; the protocol
//! and the bytes on the wire are the same.

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::Duration;

use dglmnet::cluster::transport::SocketTransport;
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::store::ShardStore;
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

const MACHINES: usize = 2;

fn dataset() -> Dataset {
    // webspam-like (p >> n): the regime where the allgather-Δβ gather wins
    synth::webspam_like(600, 4_000, 10, 99)
}

fn config(lambda: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(MACHINES)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(10)
        .build()
}

fn worker_main(machine: usize, addr: &str) -> Result<(), Box<dyn std::error::Error>> {
    let ds = dataset();
    let lam = lambda_max(&ds) / 4.0;
    let cfg = config(lam);
    let shard = DGlmnetSolver::shard_for(&ds, &cfg, machine);
    let mut node = WorkerNode::from_shard(
        &cfg,
        shard,
        std::sync::Arc::new(ds.y.clone()),
        ds.n_features(),
        std::path::Path::new("artifacts"),
    )?;
    println!(
        "[worker {machine}] pid {}: shard ready, joining {addr}",
        std::process::id()
    );
    let mut transport = SocketTransport::connect_retry(addr, Duration::from_secs(30))?;
    node.serve(&mut transport, None)?;
    println!("[worker {machine}] pid {}: shutdown", std::process::id());
    Ok(())
}

/// Act-2 worker: no dataset regeneration — open the store and read *only*
/// this machine's shard file.
fn worker_store_main(
    machine: usize,
    addr: &str,
    dir: &str,
) -> Result<(), Box<dyn std::error::Error>> {
    // no dataset regeneration here: λ arrives with every Sweep request, so
    // the worker's config only pins the engine and machine count
    let cfg = config(1.0);
    let store = ShardStore::open(dir)?;
    let mut node =
        WorkerNode::from_store(&cfg, &store, machine, std::path::Path::new("artifacts"))?;
    println!(
        "[store worker {machine}] pid {}: loaded shard_{machine:04}.bfcsc, joining {addr}",
        std::process::id()
    );
    let mut transport = SocketTransport::connect_retry(addr, Duration::from_secs(30))?;
    node.serve(&mut transport, None)?;
    println!("[store worker {machine}] pid {}: shutdown", std::process::id());
    Ok(())
}

struct RunOutcome {
    objective_bits: u64,
    comm_bytes: u64,
    beta: Vec<f32>,
}

fn wait_all(children: Vec<Child>) -> Result<(), Box<dyn std::error::Error>> {
    for mut child in children {
        let status = child.wait()?;
        if !status.success() {
            return Err(format!("a worker process exited with {status}").into());
        }
    }
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    if args.len() == 4 && args[1] == "worker" {
        return worker_main(args[2].parse()?, &args[3]);
    }
    if args.len() == 5 && args[1] == "worker-store" {
        return worker_store_main(args[2].parse()?, &args[3], &args[4]);
    }

    let ds = dataset();
    let lam = lambda_max(&ds) / 4.0;
    let cfg = config(lam);
    let exe = std::env::current_exe()?;

    // ---- act 1: data-flag workers --------------------------------------
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!(
        "[leader] pid {}: listening on {addr}, spawning {MACHINES} worker processes",
        std::process::id()
    );
    let children: Vec<Child> = (0..MACHINES)
        .map(|k| Command::new(&exe).arg("worker").arg(k.to_string()).arg(&addr).spawn())
        .collect::<std::io::Result<_>>()?;
    let mut socket_solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener)?;
    let fit_socket = socket_solver.fit_lambda(lam)?;
    let socket = RunOutcome {
        objective_bits: fit_socket.objective.to_bits(),
        comm_bytes: fit_socket.comm_bytes,
        beta: socket_solver.beta.clone(),
    };
    drop(socket_solver); // sends Shutdown; the worker processes exit
    wait_all(children)?;

    // ---- act 2: sharded-store workers, O(n) leader ---------------------
    let store_dir = std::env::temp_dir()
        .join(format!("dglmnet_example_store_{}", std::process::id()));
    let partition = DGlmnetSolver::partition_for(&ds, &cfg);
    let store = ShardStore::create(&store_dir, &ds, &partition, "round-robin")?;
    println!(
        "[leader] store written to {} ({MACHINES} shard files + manifest + y.bin)",
        store_dir.display()
    );
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr2 = listener.local_addr()?.to_string();
    let children: Vec<Child> = (0..MACHINES)
        .map(|k| {
            Command::new(&exe)
                .arg("worker-store")
                .arg(k.to_string())
                .arg(&addr2)
                .arg(store_dir.as_os_str())
                .spawn()
        })
        .collect::<std::io::Result<_>>()?;
    let mut store_solver = DGlmnetSolver::from_store_socket(&store, &cfg, listener)?;
    let fit_store = store_solver.fit_lambda(lam)?;
    let stored = RunOutcome {
        objective_bits: fit_store.objective.to_bits(),
        comm_bytes: fit_store.comm_bytes,
        beta: store_solver.beta.clone(),
    };
    drop(store_solver);
    wait_all(children)?;
    std::fs::remove_dir_all(&store_dir).ok();

    // ---- reference: in-process -----------------------------------------
    let mut local_solver = DGlmnetSolver::from_dataset(&ds, &cfg)?;
    let fit_local = local_solver.fit_lambda(lam)?;

    println!(
        "[leader] socket      : f = {:.6} ({} iters, {} comm bytes)",
        fit_socket.objective, fit_socket.iterations, fit_socket.comm_bytes
    );
    println!(
        "[leader] store-socket: f = {:.6} ({} iters, {} comm bytes)",
        fit_store.objective, fit_store.iterations, fit_store.comm_bytes
    );
    println!(
        "[leader] in-process  : f = {:.6} ({} iters, {} comm bytes)",
        fit_local.objective, fit_local.iterations, fit_local.comm_bytes
    );
    if let Some(rss) = dglmnet::util::peak_rss_bytes() {
        println!(
            "[leader] peak RSS {:.1} MiB (store-driven leader holds y + margins, never X)",
            rss as f64 / (1u64 << 20) as f64
        );
    }
    let local_bits = fit_local.objective.to_bits();
    let bit_identical = socket.objective_bits == local_bits
        && stored.objective_bits == local_bits
        && socket.beta == local_solver.beta
        && stored.beta == local_solver.beta
        && socket.comm_bytes == fit_local.comm_bytes
        && stored.comm_bytes == fit_local.comm_bytes;
    println!("[leader] bit-identical across all three runs: {bit_identical}");
    println!("objective_bits={:016x}", fit_socket.objective.to_bits());
    if !bit_identical {
        return Err("socket / store / in-process runs diverged".into());
    }
    Ok(())
}
