//! A genuinely multi-process d-GLMNET fit over the socket transport.
//!
//! The leader process binds an ephemeral TCP port and re-executes *itself*
//! twice with `worker <machine> <addr>` arguments — two real OS processes,
//! each rebuilding its feature shard deterministically from the same
//! synthetic dataset, connecting back, and serving the node protocol. The
//! leader then runs the identical fit with in-process worker threads and
//! verifies the two trajectories are bit-identical (objective, β, and the
//! comm-bytes ledger) — the property the CI socket job gates on.
//!
//! Run: `cargo run --release --example socket_cluster`
//!
//! Production deployments use the `dglmnet worker` CLI subcommand instead
//! of the self-exec trick; the protocol and the bytes on the wire are the
//! same.

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::Duration;

use dglmnet::cluster::transport::SocketTransport;
use dglmnet::cluster::WorkerNode;
use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::dataset::Dataset;
use dglmnet::data::synth;
use dglmnet::solver::{lambda_max, DGlmnetSolver};

const MACHINES: usize = 2;

fn dataset() -> Dataset {
    // webspam-like (p >> n): the regime where the allgather-Δβ gather wins
    synth::webspam_like(600, 4_000, 10, 99)
}

fn config(lambda: f64) -> TrainConfig {
    TrainConfig::builder()
        .machines(MACHINES)
        .engine(EngineKind::Native)
        .lambda(lambda)
        .max_iter(10)
        .build()
}

fn worker_main(machine: usize, addr: &str) -> Result<(), Box<dyn std::error::Error>> {
    let ds = dataset();
    let lam = lambda_max(&ds) / 4.0;
    let cfg = config(lam);
    let shard = DGlmnetSolver::shard_for(&ds, &cfg, machine);
    let mut node = WorkerNode::from_shard(
        &cfg,
        shard,
        std::sync::Arc::new(ds.y.clone()),
        ds.n_features(),
        std::path::Path::new("artifacts"),
    )?;
    println!(
        "[worker {machine}] pid {}: shard ready, joining {addr}",
        std::process::id()
    );
    let mut transport = SocketTransport::connect_retry(addr, Duration::from_secs(30))?;
    node.serve(&mut transport)?;
    println!("[worker {machine}] pid {}: shutdown", std::process::id());
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    if args.len() == 4 && args[1] == "worker" {
        return worker_main(args[2].parse()?, &args[3]);
    }

    let ds = dataset();
    let lam = lambda_max(&ds) / 4.0;
    let cfg = config(lam);

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!(
        "[leader] pid {}: listening on {addr}, spawning {MACHINES} worker processes",
        std::process::id()
    );
    let exe = std::env::current_exe()?;
    let children: Vec<Child> = (0..MACHINES)
        .map(|k| Command::new(&exe).arg("worker").arg(k.to_string()).arg(&addr).spawn())
        .collect::<std::io::Result<_>>()?;

    let mut socket_solver = DGlmnetSolver::from_dataset_socket(&ds, &cfg, listener)?;
    let fit_socket = socket_solver.fit_lambda(lam)?;
    let beta_socket = socket_solver.beta.clone();
    drop(socket_solver); // sends Shutdown; the worker processes exit
    for mut child in children {
        let status = child.wait()?;
        if !status.success() {
            return Err(format!("a worker process exited with {status}").into());
        }
    }

    let mut local_solver = DGlmnetSolver::from_dataset(&ds, &cfg)?;
    let fit_local = local_solver.fit_lambda(lam)?;

    println!(
        "[leader] socket    : f = {:.6} ({} iters, {} comm bytes)",
        fit_socket.objective, fit_socket.iterations, fit_socket.comm_bytes
    );
    println!(
        "[leader] in-process: f = {:.6} ({} iters, {} comm bytes)",
        fit_local.objective, fit_local.iterations, fit_local.comm_bytes
    );
    let bit_identical = fit_socket.objective.to_bits() == fit_local.objective.to_bits()
        && beta_socket == local_solver.beta
        && fit_socket.comm_bytes == fit_local.comm_bytes;
    println!("[leader] bit-identical across transports: {bit_identical}");
    println!("objective_bits={:016x}", fit_socket.objective.to_bits());
    if !bit_identical {
        return Err("socket and in-process runs diverged".into());
    }
    Ok(())
}
