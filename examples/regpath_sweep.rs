//! Regularization-path sweep on a webspam-like sparse problem (paper §4.2 /
//! Algorithm 5): λ_max halved 12 times with warmstarts, test AUPRC and
//! sparsity per λ, CSV + ASCII plot output.
//!
//! Run: `cargo run --release --example regpath_sweep`

use dglmnet::config::{EngineKind, TrainConfig};
use dglmnet::data::synth;
use dglmnet::report::{ascii_scatter, write_series_csv, Series};
use dglmnet::solver::{lambda_max, DGlmnetSolver, RegPath};

fn main() -> dglmnet::Result<()> {
    let ds = synth::webspam_like(3_000, 8_000, 40, 7);
    let split = ds.split(0.8, 7).unwrap();
    println!(
        "webspam-like: {} train examples, {} features (sparse, p >> n)",
        split.train.n_examples(),
        split.train.n_features()
    );

    let engine = EngineKind::Auto; // per-shard XLA/native routing
    let cfg = TrainConfig::builder()
        .machines(8)
        .engine(engine)
        .max_iter(40)
        .build();

    // the estimator-generic path runner: build the λ ladder explicitly and
    // hand the solver over as `&mut dyn Estimator` — swap in a baseline
    // estimator and this sweep runs the identical protocol
    let lam_max = lambda_max(&split.train);
    let lambdas: Vec<f64> = (1..=12).map(|i| lam_max * 0.5f64.powi(i)).collect();
    let mut solver = DGlmnetSolver::from_dataset(&split.train, &cfg)?;
    let path = RegPath::run_estimator(&mut solver, &split.train, &split.test, &lambdas)?;

    println!("\nlambda      nnz     AUPRC    AUC      iters  wall(s)");
    for p in &path.points {
        println!(
            "{:<10.4}  {:<6}  {:.4}   {:.4}   {:<5}  {:.2}",
            p.lambda, p.nnz, p.auprc, p.auc, p.iterations, p.wall_secs
        );
    }
    println!(
        "\ntotal: {} iterations, {:.1}s wall, line search = {:.0}% of solver time",
        path.total_iterations,
        path.total_wall_secs,
        path.line_search_frac * 100.0
    );

    let mut series = Series::new("d-glmnet");
    for p in &path.points {
        if p.nnz > 0 {
            series.push((p.nnz as f64).log10(), p.auprc);
        }
    }
    println!("\ntest AUPRC vs log10(nnz):");
    print!("{}", ascii_scatter(&[series.clone()], 64, 16));
    write_series_csv("target/regpath_sweep.csv", &[series])?;
    println!("wrote target/regpath_sweep.csv");
    Ok(())
}
